//! The tile-plan autotuner acceptance grid: calibrated blocking and
//! band-split choices must be **observationally invisible** — every
//! tuned GEMM bit-identical to the untuned default across the
//! 5-architecture × 4-variant grid, autotuned serving runs bit-identical
//! to untuned runs through the continuous scheduler (composing with
//! prefix sharing, KV prepacking, and oracle speculation), and the
//! planner's event model invariant under the entire tuning space. The
//! tuner may move time, never values and never counted events.

use ent::arch::{gemm_ref, ArchKind, Tcu, TcuEngine, Tuned, ALL_ARCHS};
use ent::coordinator::batcher::ContinuousPolicy;
use ent::coordinator::{Config, Coordinator, DraftKind, Spec, TokenRequest};
use ent::nn::transformer::QuantTransformer;
use ent::pe::Variant;
use ent::sim::autotune::PlanTuner;
use ent::sim::{GemmShape, TilePlan};
use ent::util::prng::Rng;

fn prompt(len: usize, salt: usize) -> Vec<u16> {
    (0..len).map(|i| ((i * 11 + salt * 17 + 2) % 64) as u16).collect()
}

/// Sequential ground truth on one engine of the native shard geometry
/// (size 16; cube edge 8), no tuner attached.
fn sequential(arch: ArchKind, tokens: &[u16], max_new: usize) -> (Vec<f32>, Vec<u16>) {
    let model = QuantTransformer::tiny_native();
    let size = if arch == ArchKind::Cube3d { 8 } else { 16 };
    let eng = Tcu::new(arch, size, Variant::EntOurs).engine();
    model.generate(&eng, tokens, max_new)
}

/// The serving shapes the schedulers actually issue: a CNN im2col
/// tile, a prefill QKV projection, an m=1 decode row, and a
/// speculative verify window (1 carried + 4 drafted rows).
const SHAPES: [(usize, usize, usize); 4] = [(36, 27, 16), (16, 32, 32), (1, 32, 64), (5, 8, 64)];

/// The headline invariant: a [`Tuned`] engine view returns exactly the
/// integers of the bare engine (and of the reference GEMM) for every
/// architecture, every PE variant, and every serving shape class —
/// whatever blocking or band split the calibration loop picked.
#[test]
fn tuned_matmul_bit_identical_across_arch_variant_grid() {
    let mut rng = Rng::new(0xA1);
    for arch in ALL_ARCHS {
        for variant in Variant::ALL {
            let size = if arch == ArchKind::Cube3d { 8 } else { 16 };
            let eng = Tcu::new(arch, size, variant).engine();
            let tuner = PlanTuner::new();
            let tuned = Tuned::new(&eng, Some(&tuner));
            for (m, k, n) in SHAPES {
                let a = rng.i8_vec(m * k);
                let b = rng.i8_vec(k * n);
                let want = gemm_ref(&a, &b, m, k, n);
                assert_eq!(
                    eng.matmul(&a, &b, m, k, n),
                    want,
                    "{} {} bare engine diverged on {m}x{k}x{n}",
                    arch.name(),
                    variant.name()
                );
                // Twice through the tuner: the first call calibrates,
                // the second replays the cached winner — both must be
                // bit-identical to the reference.
                for pass in 0..2 {
                    assert_eq!(
                        tuned.matmul(&a, &b, m, k, n),
                        want,
                        "{} {} tuned engine diverged on {m}x{k}x{n} (pass {pass})",
                        arch.name(),
                        variant.name()
                    );
                }
            }
            let s = tuner.stats();
            assert!(s.tunes >= 1, "tuner never calibrated");
            assert!(s.hits >= 1, "second passes should hit the plan cache");
        }
    }
}

/// A `Tuned` view with no tuner attached is an exact pass-through —
/// the wrapper itself cannot perturb anything.
#[test]
fn tuned_view_without_tuner_is_passthrough() {
    let mut rng = Rng::new(0xA2);
    let eng = Tcu::new(ArchKind::SystolicOs, 16, Variant::EntOurs).engine();
    let view = Tuned::new(&eng, None);
    for (m, k, n) in SHAPES {
        let a = rng.i8_vec(m * k);
        let b = rng.i8_vec(k * n);
        assert_eq!(view.matmul(&a, &b, m, k, n), gemm_ref(&a, &b, m, k, n));
    }
}

/// Autotuned serving ≡ untuned serving through the continuous
/// scheduler, across all five architectures: same logits, same
/// generated tokens, and both equal to sequential decode. The tuned
/// run's metrics must surface live tuner counters; the untuned run
/// must not have a tuner at all.
#[test]
fn autotune_on_matches_off_through_continuous_scheduler() {
    let requests: [(usize, usize); 3] = [(5, 3), (8, 1), (3, 4)];
    for arch in ALL_ARCHS {
        let run = |autotune: bool| {
            let cfg = Config::builder()
                .continuous(2)
                .twin(arch, Variant::EntOurs)
                .policy(ContinuousPolicy {
                    prefill_chunk: 3,
                    ..ContinuousPolicy::default()
                })
                .autotune(autotune)
                .build()
                .expect("config");
            let coord = Coordinator::start(cfg).expect("coordinator");
            let rxs: Vec<_> = requests
                .iter()
                .enumerate()
                .map(|(salt, &(plen, gen))| {
                    coord.submit_tokens(TokenRequest::generate(prompt(plen, salt), gen))
                })
                .collect();
            let results: Vec<_> = rxs
                .into_iter()
                .map(|rx| rx.recv().expect("scheduler alive").expect("request ok"))
                .collect();
            let m = coord.metrics();
            coord.shutdown();
            (results, m)
        };
        let (on, m_on) = run(true);
        let (off, m_off) = run(false);
        for (i, (r_on, r_off)) in on.iter().zip(&off).enumerate() {
            assert_eq!(
                r_on.logits,
                r_off.logits,
                "{} request {i}: autotune changed logits",
                arch.name()
            );
            assert_eq!(
                r_on.generated,
                r_off.generated,
                "{} request {i}: autotune changed generation",
                arch.name()
            );
            let (seq_logits, seq_gen) =
                sequential(arch, &prompt(requests[i].0, i), requests[i].1);
            assert_eq!(r_on.logits, seq_logits, "{} request {i}", arch.name());
            assert_eq!(r_on.generated, seq_gen, "{} request {i}", arch.name());
        }
        let ts = m_on.plan_tuner.expect("autotuned run must surface tuner counters");
        assert!(
            ts.hits + ts.misses > 0,
            "{}: shards never consulted the tuner",
            arch.name()
        );
        assert!(ts.tunes >= 1, "{}: no calibration ran", arch.name());
        assert!(ts.entries >= 1 && ts.entries <= ts.capacity);
        assert!(m_off.plan_tuner.is_none(), "untuned run grew a tuner");
        assert_eq!(m_on.errors, 0);
        assert_eq!(m_off.errors, 0);
    }
}

/// Autotuning composes with the rest of the serving stack: prefix
/// sharing (two requests share a prompt), KV prepacking, and oracle
/// speculation all enabled — tuned ≡ untuned ≡ sequential, still
/// bit-exact.
#[test]
fn autotune_composes_with_share_prepack_and_speculation() {
    let shared = prompt(7, 3);
    let other = prompt(5, 8);
    let run = |autotune: bool| {
        let cfg = Config::builder()
            .continuous(2)
            .twin(ArchKind::SystolicOs, Variant::EntOurs)
            .policy(ContinuousPolicy {
                prefill_chunk: 3,
                ..ContinuousPolicy::default()
            })
            .prefix_share(true)
            .kv_prepack(true)
            .speculation(Spec::On { k: 4, draft: DraftKind::Oracle })
            .autotune(autotune)
            .build()
            .expect("config");
        let coord = Coordinator::start(cfg).expect("coordinator");
        let rxs = vec![
            coord.submit_tokens(TokenRequest::generate(shared.clone(), 4)),
            coord.submit_tokens(TokenRequest::generate(shared.clone(), 2)),
            coord.submit_tokens(TokenRequest::generate(other.clone(), 3)),
        ];
        let results: Vec<_> = rxs
            .into_iter()
            .map(|rx| rx.recv().expect("scheduler alive").expect("request ok"))
            .collect();
        coord.shutdown();
        results
    };
    let on = run(true);
    let off = run(false);
    for (i, (r_on, r_off)) in on.iter().zip(&off).enumerate() {
        assert_eq!(r_on.logits, r_off.logits, "request {i}: logits diverged");
        assert_eq!(r_on.generated, r_off.generated, "request {i}: generation diverged");
    }
    let (want_logits, want_gen) = sequential(ArchKind::SystolicOs, &shared, 4);
    assert_eq!(on[0].logits, want_logits);
    assert_eq!(on[0].generated, want_gen);
    let (want_logits, want_gen) = sequential(ArchKind::SystolicOs, &other, 3);
    assert_eq!(on[2].logits, want_logits);
    assert_eq!(on[2].generated, want_gen);
}

/// Seeded shape-fuzz over the tuning space: for random shapes —
/// including m=1 decode rows, odd/prime contraction and output dims,
/// and sub-tile problems — every blocking request materializes in-cap,
/// and the planner's event model (`stats`, `stats_cached`,
/// `stats_kv_prepacked`) is **invariant** under the blocking. Tuned
/// plans additionally execute bit-identically to the reference GEMM.
#[test]
fn shape_fuzz_stats_invariant_under_blocking() {
    let dims: [usize; 12] = [1, 2, 3, 5, 7, 11, 13, 17, 23, 29, 31, 64];
    let ms: [usize; 8] = [1, 1, 1, 2, 3, 5, 13, 48];
    let mut rng = Rng::new(0xF022);
    for round in 0..60 {
        let arch = *rng.pick(&ALL_ARCHS);
        let variant = *rng.pick(&Variant::ALL);
        let size = *rng.pick(&[4usize, 8, 16]);
        let tcu = Tcu::new(arch, size, variant);
        let (cap_m, cap_k, cap_n) = tcu.tile_caps();
        let g = GemmShape::new(*rng.pick(&ms), *rng.pick(&dims), *rng.pick(&dims));
        let def = TilePlan::new(&tcu, g);
        let base = def.stats();
        let base_cached = def.stats_cached();
        let fresh = rng.below(1 + base.macs);
        let base_kv = def.stats_kv_prepacked(fresh);
        // Random blocking requests, deliberately including out-of-range
        // extents — with_blocking must clamp them into cap and shape.
        for _ in 0..4 {
            let tm = rng.range(1, 2 * g.m + 2);
            let tk = rng.range(1, 2 * g.k + 2);
            let tn = rng.range(1, 2 * g.n + 2);
            let plan = TilePlan::with_blocking(&tcu, g, tm, tk, tn);
            assert!(plan.tm >= 1 && plan.tm <= cap_m.min(g.m), "round {round}: tm cap");
            assert!(plan.tk >= 1 && plan.tk <= cap_k.min(g.k), "round {round}: tk cap");
            assert!(plan.tn >= 1 && plan.tn <= cap_n.min(g.n), "round {round}: tn cap");
            let st = plan.stats();
            assert_eq!(st.macs, base.macs, "round {round}: MACs moved under blocking");
            assert_eq!(st.cycles, base.cycles, "round {round}: cycles moved");
            assert_eq!(st.encodes, base.encodes, "round {round}: encodes moved");
            assert_eq!(st.weight_encodes, base.weight_encodes, "round {round}");
            assert_eq!(st.a_reads, base.a_reads, "round {round}: A reads moved");
            assert_eq!(st.b_reads, base.b_reads, "round {round}: B reads moved");
            assert_eq!(st.psum_spills, base.psum_spills, "round {round}");
            let sc = plan.stats_cached();
            assert_eq!(sc.encodes, base_cached.encodes, "round {round}: cached encodes");
            assert_eq!(sc.macs, base_cached.macs, "round {round}");
            let skv = plan.stats_kv_prepacked(fresh);
            assert_eq!(skv.encodes, base_kv.encodes, "round {round}: kv encodes");
            assert_eq!(skv.macs, base_kv.macs, "round {round}");
        }
        // The tuner's own pick for this shape: in-cap, sane band count,
        // and bit-identical execution.
        let eng = tcu.engine();
        let tuner = PlanTuner::new();
        let (plan, bands) = tuner.choose(&eng, g);
        assert!(plan.tm >= 1 && plan.tm <= cap_m.min(g.m));
        assert!(plan.tk >= 1 && plan.tk <= cap_k.min(g.k));
        assert!(plan.tn >= 1 && plan.tn <= cap_n.min(g.n));
        assert!(bands >= 1 && bands <= g.m);
        let a = rng.i8_vec(g.m * g.k);
        let b = rng.i8_vec(g.k * g.n);
        let mut c = vec![0i64; g.m * g.n];
        eng.matmul_into_planned(&a, &b, &mut c, &plan, bands);
        assert_eq!(
            c,
            gemm_ref(&a, &b, g.m, g.k, g.n),
            "round {round}: tuned plan changed values on {}x{}x{} {}",
            g.m,
            g.k,
            g.n,
            arch.name()
        );
    }
}
