//! System-level invariants across modules — the properties the paper's
//! claims rest on, checked end-to-end (no artifacts needed).

use ent::arch::{gemm_ref, ArchKind, Scale, Tcu, ALL_ARCHS, ALL_SCALES};
use ent::nn::zoo;
use ent::pe::Variant;
use ent::sim::{gemm_stats, tiled_matmul, GemmShape};
use ent::soc::{energy, Soc};
use ent::util::check::{check, Config};

/// EN-T is functionally invisible: every architecture × variant × shape
/// computes the exact same GEMM (property-based, random shapes).
#[test]
fn ent_is_functionally_invisible() {
    check(
        "arch-variant-equivalence",
        Config { cases: 40, seed: 0xD1 },
        |rng| {
            let arch = *rng.pick(&ALL_ARCHS);
            let size = if arch == ArchKind::Cube3d { 4 } else { 8 };
            let m = rng.range(1, 12);
            let k = rng.range(1, 20);
            let n = rng.range(1, 12);
            let a = rng.i8_vec(m * k);
            let b = rng.i8_vec(k * n);
            let want = gemm_ref(&a, &b, m, k, n);
            for variant in Variant::ALL {
                let tcu = Tcu::new(arch, size, variant);
                let got = tiled_matmul(&tcu, &a, &b, m, k, n);
                if got != want {
                    return Err(format!(
                        "{} {} {m}x{k}x{n} mismatch",
                        arch.name(),
                        variant.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The paper's headline orderings at every computational scale.
#[test]
fn efficiency_orderings_hold_at_all_scales() {
    for scale in ALL_SCALES {
        for arch in ALL_ARCHS {
            let s = arch.size_for_scale(scale);
            let base = Tcu::new(arch, s, Variant::Baseline);
            let ours = Tcu::new(arch, s, Variant::EntOurs);
            // EN-T(Ours) always improves both efficiencies.
            assert!(
                ours.area_efficiency() > base.area_efficiency(),
                "{} {}",
                arch.name(),
                scale.name()
            );
            assert!(
                ours.energy_efficiency() > base.energy_efficiency(),
                "{} {}",
                arch.name(),
                scale.name()
            );
            // And beats EN-T(MBE) on pipelined-transfer architectures
            // (the encoded-width argument).
            if arch.pipelined_transfer() {
                let mbe = Tcu::new(arch, s, Variant::EntMbe);
                assert!(
                    ours.area_efficiency() > mbe.area_efficiency(),
                    "{} {}",
                    arch.name(),
                    scale.name()
                );
            }
        }
    }
}

/// Fig 7's scale trend: the average up-ratio at 1 TOPS exceeds the one
/// at 256 GOPS (encoder amortization improves with array size).
#[test]
fn gains_grow_from_256g_to_1t() {
    let avg = |scale: Scale| {
        ALL_ARCHS
            .iter()
            .map(|&arch| {
                let s = arch.size_for_scale(scale);
                let b = Tcu::new(arch, s, Variant::Baseline);
                let e = Tcu::new(arch, s, Variant::EntOurs);
                e.area_efficiency() / b.area_efficiency() - 1.0
            })
            .sum::<f64>()
            / ALL_ARCHS.len() as f64
    };
    assert!(avg(Scale::Tops1) > avg(Scale::Gops256));
}

/// SoC energy accounting is self-consistent: totals equal the sum of
/// buckets, and EN-T only changes the TCU bucket materially.
#[test]
fn soc_buckets_are_consistent() {
    let net = zoo::by_name("resnet34").unwrap();
    for arch in ALL_ARCHS {
        let base = energy::frame_energy(&Soc::paper_config(arch, Variant::Baseline), &net).0;
        let ours = energy::frame_energy(&Soc::paper_config(arch, Variant::EntOurs), &net).0;
        // SRAM traffic is variant-independent (the transformation is
        // inside the array).
        assert!(
            (base.sram_read_pj - ours.sram_read_pj).abs() / base.sram_read_pj < 1e-9,
            "{}",
            arch.name()
        );
        // TCU bucket strictly shrinks.
        assert!(ours.tcu_pj < base.tcu_pj, "{}", arch.name());
        // Cycle counts are identical — EN-T does not change timing.
        assert_eq!(base.cycles, ours.cycles, "{}", arch.name());
    }
}

/// Utilization monotonicity: bigger arrays never *increase* utilization
/// on a fixed ragged workload (tile-quantization effect the Fig 7 dip
/// discussion rests on).
#[test]
fn utilization_degrades_with_array_size_on_ragged_shapes() {
    let g = GemmShape::new(48, 100, 48); // deliberately ragged
    let mut prev = f64::MAX;
    for s in [16usize, 32, 64] {
        let tcu = Tcu::new(ArchKind::SystolicOs, s, Variant::Baseline);
        let u = gemm_stats(&tcu, g).utilization;
        assert!(u <= prev + 1e-12, "S={s}: {u} > {prev}");
        prev = u;
    }
}

/// The Table 2 SoC assembles to the published 1024 GOPS with the
/// published encoder counts for every architecture.
#[test]
fn soc_matches_section_4_4_grid() {
    for arch in ALL_ARCHS {
        let soc = Soc::paper_config(arch, Variant::EntOurs);
        assert_eq!(soc.gops(), 1024.0, "{}", arch.name());
        let expect_encoders = if arch == ArchKind::Cube3d { 128 } else { 32 };
        assert_eq!(soc.encoder_blocks(), expect_encoders, "{}", arch.name());
    }
}

/// Energy reductions (Fig 11) stay positive for every paper network on
/// every architecture, with the cube last as §4.4 argues.
#[test]
fn fig11_shape_holds_across_all_networks() {
    let mut cube_max: f64 = 0.0;
    let mut broadcast_min = f64::MAX;
    for net in zoo::paper_networks() {
        for arch in ALL_ARCHS {
            let r = energy::reduction_ratio(arch, &net);
            assert!(r > 0.0, "{} {}: {r}", arch.name(), net.name);
            match arch {
                ArchKind::Cube3d => cube_max = cube_max.max(r),
                ArchKind::Matrix2d | ArchKind::Array1d2d => {
                    broadcast_min = broadcast_min.min(r)
                }
                _ => {}
            }
        }
    }
    assert!(
        cube_max < broadcast_min,
        "cube best {cube_max:.3} should trail broadcast worst {broadcast_min:.3}"
    );
}
