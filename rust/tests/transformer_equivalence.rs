//! The transformer acceptance grid: functional transparency of the
//! attention workload across every architecture × variant, invariance
//! under batching and sharding through the coordinator, and the
//! KV-cache MAC saving asserted through the planner's event counts.

use ent::arch::{ArchKind, Tcu, ALL_ARCHS};
use ent::coordinator::{Config, Coordinator, TokenRequest};
use ent::nn::transformer::{QuantTransformer, TransformerSpec};
use ent::pe::Variant;
use ent::soc::{energy, Soc};

fn prompt(n: usize) -> Vec<u16> {
    (0..n).map(|i| ((i * 13 + 5) % 64) as u16).collect()
}

/// The paper's functional-transparency claim at transformer scope:
/// every architecture × every variant in [`Variant::ALL`] (Baseline,
/// EN-T(MBE), EN-T(Ours), BW-T) produces bit-identical next-token
/// logits, through every GEMM of the encoder stack (projections,
/// per-head attention contractions, MLP, head).
#[test]
fn transformer_logits_identical_across_all_arch_variants() {
    let model = QuantTransformer::tiny_native();
    let toks = prompt(8);
    let reference = model.logits(
        &Tcu::new(ArchKind::Matrix2d, 16, Variant::Baseline).engine(),
        &toks,
    );
    assert!(reference.iter().any(|&x| x != reference[0]), "degenerate");
    for arch in ALL_ARCHS {
        let size = if arch == ArchKind::Cube3d { 4 } else { 8 };
        for variant in Variant::ALL {
            let eng = Tcu::new(arch, size, variant).engine();
            assert_eq!(
                model.logits(&eng, &toks),
                reference,
                "{} {}",
                arch.name(),
                variant.name()
            );
        }
    }
}

/// Logits are invariant under batch grouping and shard count: the same
/// sequence served solo on one shard, and concurrently (forcing batch
/// formation) on a larger shard pool, returns identical logits.
#[test]
fn transformer_logits_invariant_under_batch_and_shard_count() {
    let toks = prompt(6);
    let solo = {
        let cfg = Config::builder().native(1).build().expect("config");
        let coord = Coordinator::start(cfg).expect("1-shard coordinator");
        let r = coord
            .infer_tokens(TokenRequest::prefill(toks.clone()))
            .expect("solo token inference");
        coord.shutdown();
        r.logits
    };
    let cfg = Config::builder().native(3).build().expect("config");
    let coord = Coordinator::start(cfg).expect("3-shard coordinator");
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let coord = &coord;
            let toks = toks.clone();
            let expect = solo.clone();
            scope.spawn(move || {
                let r = coord
                    .infer_tokens(TokenRequest::prefill(toks))
                    .expect("batched token inference");
                assert_eq!(r.logits, expect, "batch/shard count changed logits");
            });
        }
    });
    let m = coord.metrics();
    assert_eq!(m.errors, 0);
    assert_eq!(m.requests, 4);
    coord.shutdown();
}

/// Sequence-length invariance of the per-position math: prefilling a
/// prompt and then decoding more tokens gives exactly the logits of
/// prefilling the longer prompt — across engines.
#[test]
fn decode_equals_recompute_on_multiple_engines() {
    let model = QuantTransformer::tiny_native();
    let toks = prompt(9);
    for (arch, size) in [(ArchKind::SystolicWs, 8), (ArchKind::Cube3d, 4)] {
        let eng = Tcu::new(arch, size, Variant::EntOurs).engine();
        let mut caches = model.empty_caches();
        let mut last = model.prefill(&eng, &toks[..5], &mut caches);
        for &t in &toks[5..] {
            last = model.decode(&eng, t, &mut caches);
        }
        assert_eq!(last, model.logits(&eng, &toks), "{}", arch.name());
    }
}

/// The KV cache's reason to exist, in planner event counts: one decode
/// step (reusing cached K/V) must cost a small fraction of the MACs of
/// recomputing the whole sequence, at every context length — and the
/// advantage must grow with context.
#[test]
fn kv_cache_decode_saves_macs_at_every_context_length() {
    let spec = TransformerSpec::tiny();
    let soc = Soc::paper_config(ArchKind::SystolicOs, Variant::EntOurs);
    let mut prev_saving = 0.0f64;
    for kv in [4usize, 16, 48] {
        // FrameEnergy::macs accumulates TilePlan::stats().macs — the
        // planner's event counts, not a hand formula.
        let decode = energy::frame_energy(&soc, &spec.decode_network(kv)).0;
        let recompute = energy::frame_energy(&soc, &spec.prefill_network(kv)).0;
        assert!(
            decode.macs * 2 < recompute.macs,
            "kv={kv}: decode {} vs recompute {}",
            decode.macs,
            recompute.macs
        );
        let saving = 1.0 - decode.macs as f64 / recompute.macs as f64;
        assert!(saving > prev_saving, "saving must grow with context");
        prev_saving = saving;
    }
    // And the energy model sees it on the base-sized spec too.
    let base = TransformerSpec::base();
    let d = energy::frame_energy(&soc, &base.decode_network(128)).0;
    let r = energy::frame_energy(&soc, &base.prefill_network(128)).0;
    assert!(d.total_pj() < r.total_pj() / 2.0);
}
