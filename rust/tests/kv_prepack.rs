//! Equivalence + accounting suite for the **append-only prepacked KV
//! cache** (`nn::attention::KvCache`'s code sidecar): decode with
//! kv-prepack on must be bit-identical to the plain path across the
//! full 5-architecture × 4-variant grid, `truncate()` must invalidate
//! exactly the dropped suffix, and — the acceptance criterion — a
//! decode step with the cache resident must charge **O(1)**
//! weight+activation encode events through the planner, independent of
//! context length, where the uncached walk charges O(seq).

use ent::arch::{ArchKind, Tcu, ALL_ARCHS};
use ent::coordinator::{Config, Coordinator, TokenRequest};
use ent::nn::transformer::{QuantTransformer, TransformerSpec};
use ent::pe::Variant;
use ent::soc::energy::{frame_energy_with, EnergyOpts};
use ent::soc::Soc;

fn prompt(n: usize) -> Vec<u16> {
    (0..n).map(|i| ((i * 7 + 3) % 64) as u16).collect()
}

/// The headline equivalence: prefill + greedy decode produce
/// bit-identical logits and tokens with kv-prepack on or off, on every
/// architecture × variant (non-EN-T engines exercise the transparent
/// fallback).
#[test]
fn decode_bit_identical_with_kv_prepack_across_grid() {
    let plain = QuantTransformer::tiny_native();
    let prepacked = QuantTransformer::tiny_native().with_kv_prepack(true);
    for arch in ALL_ARCHS {
        let size = if arch == ArchKind::Cube3d { 4 } else { 8 };
        for variant in Variant::ALL {
            let eng = Tcu::new(arch, size, variant).engine();
            let (want_logits, want_toks) = plain.generate(&eng, &prompt(5), 3);
            let (got_logits, got_toks) = prepacked.generate(&eng, &prompt(5), 3);
            assert_eq!(got_logits, want_logits, "{} {}", arch.name(), variant.name());
            assert_eq!(got_toks, want_toks, "{} {}", arch.name(), variant.name());
        }
    }
}

/// Chunked prefill through the prepacked path matches a fresh full
/// prefill — the continuous scheduler's mixed prefill/decode steps ride
/// the same sidecar.
#[test]
fn chunked_prefill_with_kv_prepack_matches_full() {
    let model = QuantTransformer::tiny_native().with_kv_prepack(true);
    let eng = Tcu::new(ArchKind::Matrix2d, 8, Variant::EntOurs).engine();
    let toks = prompt(7);
    let mut caches = model.empty_caches();
    model.prefill(&eng, &toks[..3], &mut caches);
    model.prefill(&eng, &toks[3..5], &mut caches);
    let chunked = model.prefill(&eng, &toks[5..], &mut caches);
    assert_eq!(chunked, model.logits(&eng, &toks));
}

/// `truncate()` then re-decode matches a fresh decode: the sidecar
/// invalidates exactly the dropped suffix, and the surviving prefix's
/// codes stay correct.
#[test]
fn truncate_then_redecode_matches_fresh_decode() {
    let model = QuantTransformer::tiny_native().with_kv_prepack(true);
    let eng = Tcu::new(ArchKind::SystolicWs, 8, Variant::EntOurs).engine();
    let mut caches = model.empty_caches();
    model.prefill(&eng, &prompt(5), &mut caches);
    let first = model.decode(&eng, 9, &mut caches);
    for c in caches.iter_mut() {
        c.truncate(5);
        assert_eq!(c.len(), 5);
        assert_eq!(c.encoded_len(), 5, "prefix codes must survive truncate");
    }
    let again = model.decode(&eng, 9, &mut caches);
    assert_eq!(again, first, "truncate + re-decode diverged");
    // And against a model that never prepacked at all.
    let plain = QuantTransformer::tiny_native();
    let mut fresh = plain.empty_caches();
    plain.prefill(&eng, &prompt(5), &mut fresh);
    assert_eq!(plain.decode(&eng, 9, &mut fresh), first);
}

/// The acceptance criterion, planner-verified: with the encode cache
/// and kv-prepack resident on EN-T(Ours), a decode step charges O(1)
/// weight+activation encode events — the same total at any context
/// length — while the non-prepacked walk grows with the history.
#[test]
fn decode_step_encodes_are_o1_with_kv_prepack() {
    let spec = TransformerSpec::tiny();
    let soc = Soc::paper_config(ArchKind::SystolicOs, Variant::EntOurs);
    let both = EnergyOpts {
        encode_cache: true,
        kv_prepack: true,
    };
    let short = frame_energy_with(&soc, &spec.decode_network(9), both).0;
    let long = frame_energy_with(&soc, &spec.decode_network(33), both).0;
    assert_eq!(
        short.encodes, long.encodes,
        "decode-step encodes must not grow with context length"
    );
    assert_eq!(short.weight_encodes, 0, "weights are cache-resident");
    // Exactly the K and V deltas: 2 · d_model per layer, once each.
    let expect = 2 * (spec.d_model * spec.layers) as u64;
    assert_eq!(short.encodes, expect);
    assert_eq!(short.activation_encodes, expect);
    // Without the sidecar the activation encodes are O(seq).
    let cache_only = EnergyOpts {
        encode_cache: true,
        ..Default::default()
    };
    let short_nc = frame_energy_with(&soc, &spec.decode_network(9), cache_only).0;
    let long_nc = frame_energy_with(&soc, &spec.decode_network(33), cache_only).0;
    assert!(
        long_nc.encodes > short_nc.encodes,
        "uncached attention encodes must grow with context ({} vs {})",
        long_nc.encodes,
        short_nc.encodes
    );
    assert!(long_nc.encodes > long.encodes, "prepack must shrink encode events");
    // The per-event encoder pricing follows the events.
    assert!(long.encode_pj < long_nc.encode_pj);
    assert!(long.total_pj() < long_nc.total_pj());
    // Everything that is not encoder work is untouched.
    assert_eq!(long.macs, long_nc.macs);
    assert_eq!(long.cycles, long_nc.cycles);
}

/// Non-consuming variants are indifferent to the flag — events and
/// energy are bit-for-bit unchanged (they cannot consume EN-T codes).
#[test]
fn kv_prepack_is_inert_on_non_consuming_variants() {
    let spec = TransformerSpec::tiny();
    let net = spec.decode_network(17);
    for variant in Variant::non_code_consuming() {
        let soc = Soc::paper_config(ArchKind::SystolicOs, variant);
        let plain = frame_energy_with(&soc, &net, EnergyOpts::default()).0;
        let pp = frame_energy_with(
            &soc,
            &net,
            EnergyOpts {
                kv_prepack: true,
                ..Default::default()
            },
        )
        .0;
        assert_eq!(plain.encodes, pp.encodes, "{variant:?}");
        assert_eq!(plain.total_pj(), pp.total_pj(), "{variant:?}");
    }
}

/// End-to-end through the continuous scheduler: kv-prepack on (the
/// default) serves the same logits/tokens as off, and the residency
/// counters ride the metrics snapshot.
#[test]
fn continuous_serving_kv_prepack_matches_off_and_counters_surface() {
    let on_cfg = Config::builder().continuous(2).build().expect("config");
    let on = Coordinator::start(on_cfg).expect("prepack-on coordinator");
    let off_cfg = Config::builder()
        .continuous(2)
        .kv_prepack(false)
        .build()
        .expect("config");
    let off = Coordinator::start(off_cfg).expect("prepack-off coordinator");

    let req = || TokenRequest::generate(prompt(6), 3);
    let a = on.infer_tokens(req()).expect("prepack-on serve");
    let b = off.infer_tokens(req()).expect("prepack-off serve");
    assert_eq!(a.logits, b.logits, "kv-prepack changed served logits");
    assert_eq!(a.generated, b.generated);

    let m = on.metrics();
    assert!(m.kv_rows_encoded > 0, "residency counters must surface: {m:?}");
    assert!(m.kv_rows_reused > 0, "decode must reuse cached rows: {m:?}");
    let m_off = off.metrics();
    assert_eq!((m_off.kv_rows_encoded, m_off.kv_rows_reused), (0, 0));
    on.shutdown();
    off.shutdown();
}
