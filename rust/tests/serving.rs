//! End-to-end serving integration: coordinator + dynamic batcher +
//! artifact runtime under concurrent load, including failure injection.
//! The artifact-backed tests gate on built artifacts (like
//! `cross_layer`); the native-backend tests at the bottom always run —
//! they serve straight through the engine shards.

use ent::coordinator::{Config, Coordinator, InferRequest};
use ent::runtime::default_artifact_dir;
use ent::util::prng::Rng;

fn coordinator() -> Option<Coordinator> {
    if !default_artifact_dir().join("tinynet_b1.hlo.txt").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    Some(Coordinator::start(Config::default()).expect("coordinator up"))
}

#[test]
fn serves_concurrent_requests_with_batching() {
    let Some(coord) = coordinator() else { return };
    let input_len = coord.model().input_len();
    let n_clients = 4;
    let per_client = 8;
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let coord = &coord;
            scope.spawn(move || {
                let mut rng = Rng::new(100 + c as u64);
                for _ in 0..per_client {
                    let resp = coord
                        .infer(InferRequest {
                            image: rng.i8_vec(input_len),
                        })
                        .expect("inference");
                    assert_eq!(resp.logits.len(), 10);
                    assert!(resp.logits.iter().all(|x| x.is_finite()));
                    assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
                    assert!(resp.sim_energy_uj > 0.0);
                }
            });
        }
    });
    let m = coord.metrics();
    assert_eq!(m.requests, n_clients * per_client);
    assert_eq!(m.errors, 0);
    assert!(m.mean_batch >= 1.0);
    coord.shutdown();
}

#[test]
fn identical_inputs_get_identical_logits_across_batches() {
    let Some(coord) = coordinator() else { return };
    let input_len = coord.model().input_len();
    let mut rng = Rng::new(55);
    let img = rng.i8_vec(input_len);
    let first = coord
        .infer(InferRequest { image: img.clone() })
        .expect("first");
    // Concurrent duplicates force different batch groupings.
    std::thread::scope(|scope| {
        for _ in 0..6 {
            let coord = &coord;
            let img = img.clone();
            let expect = first.logits.clone();
            scope.spawn(move || {
                let r = coord.infer(InferRequest { image: img }).expect("dup");
                assert_eq!(r.logits, expect, "batching must not change results");
            });
        }
    });
    coord.shutdown();
}

#[test]
fn malformed_request_rejected_without_poisoning_the_batch() {
    let Some(coord) = coordinator() else { return };
    let input_len = coord.model().input_len();
    // Bad request (wrong length) concurrent with good ones.
    let bad = coord.submit(InferRequest {
        image: vec![0i8; 17],
    });
    let mut rng = Rng::new(77);
    let good = coord
        .infer(InferRequest {
            image: rng.i8_vec(input_len),
        })
        .expect("good request must survive");
    assert_eq!(good.logits.len(), 10);
    let bad_result = bad.recv().expect("bad response arrives");
    let err = bad_result.expect_err("bad request must error");
    assert!(err.contains("bad input"), "{err}");
    let m = coord.metrics();
    assert!(m.errors >= 1);
    coord.shutdown();
}

/// Native backend: the full serving path (dynamic batcher → engine
/// shards → digital twin) with zero artifacts, under concurrent load.
#[test]
fn native_shards_serve_concurrent_requests() {
    let coord = Coordinator::start(Config::native(3)).expect("native coordinator");
    let input_len = coord.model().input_len();
    let n_clients = 3;
    let per_client = 3;
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let coord = &coord;
            scope.spawn(move || {
                let mut rng = Rng::new(400 + c as u64);
                for _ in 0..per_client {
                    let resp = coord
                        .infer(InferRequest {
                            image: rng.i8_vec(input_len),
                        })
                        .expect("native inference");
                    assert_eq!(resp.logits.len(), 10);
                    assert!(resp.logits.iter().all(|x| x.is_finite()));
                    assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
                    assert!(resp.sim_energy_uj > 0.0);
                }
            });
        }
    });
    let m = coord.metrics();
    assert_eq!(m.requests, n_clients * per_client);
    assert_eq!(m.errors, 0);
    coord.shutdown();
}
