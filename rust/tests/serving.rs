//! End-to-end serving integration: coordinator + dynamic batcher +
//! artifact runtime under concurrent load, including failure injection.
//! The artifact-backed tests gate on built artifacts (like
//! `cross_layer`); the native-backend tests always run — they serve
//! straight through the engine shards. The mixed-traffic tests at the
//! bottom cover the continuous-batching scheduler's fairness: CNN jobs
//! and token requests interleaved through one coordinator, with no
//! starvation and results identical to isolated runs.

use ent::arch::{ArchKind, Tcu};
use ent::coordinator::batcher::ContinuousPolicy;
use ent::coordinator::{Config, Coordinator, DraftKind, InferRequest, ServeMode, TokenRequest};
use ent::nn::forward::QuantCnn;
use ent::nn::transformer::QuantTransformer;
use ent::pe::Variant;
use ent::runtime::default_artifact_dir;
use ent::util::prng::Rng;

fn coordinator() -> Option<Coordinator> {
    if !default_artifact_dir().join("tinynet_b1.hlo.txt").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    Some(Coordinator::start(Config::default()).expect("coordinator up"))
}

#[test]
fn serves_concurrent_requests_with_batching() {
    let Some(coord) = coordinator() else { return };
    let input_len = coord.model().input_len();
    let n_clients = 4;
    let per_client = 8;
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let coord = &coord;
            scope.spawn(move || {
                let mut rng = Rng::new(100 + c as u64);
                for _ in 0..per_client {
                    let resp = coord
                        .infer(InferRequest {
                            image: rng.i8_vec(input_len),
                        })
                        .expect("inference");
                    assert_eq!(resp.logits.len(), 10);
                    assert!(resp.logits.iter().all(|x| x.is_finite()));
                    assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
                    assert!(resp.sim_energy_uj > 0.0);
                }
            });
        }
    });
    let m = coord.metrics();
    assert_eq!(m.requests, n_clients * per_client);
    assert_eq!(m.errors, 0);
    assert!(m.mean_batch >= 1.0);
    coord.shutdown();
}

#[test]
fn identical_inputs_get_identical_logits_across_batches() {
    let Some(coord) = coordinator() else { return };
    let input_len = coord.model().input_len();
    let mut rng = Rng::new(55);
    let img = rng.i8_vec(input_len);
    let first = coord
        .infer(InferRequest { image: img.clone() })
        .expect("first");
    // Concurrent duplicates force different batch groupings.
    std::thread::scope(|scope| {
        for _ in 0..6 {
            let coord = &coord;
            let img = img.clone();
            let expect = first.logits.clone();
            scope.spawn(move || {
                let r = coord.infer(InferRequest { image: img }).expect("dup");
                assert_eq!(r.logits, expect, "batching must not change results");
            });
        }
    });
    coord.shutdown();
}

#[test]
fn malformed_request_rejected_without_poisoning_the_batch() {
    let Some(coord) = coordinator() else { return };
    let input_len = coord.model().input_len();
    // Bad request (wrong length) concurrent with good ones.
    let bad = coord.submit(InferRequest {
        image: vec![0i8; 17],
    });
    let mut rng = Rng::new(77);
    let good = coord
        .infer(InferRequest {
            image: rng.i8_vec(input_len),
        })
        .expect("good request must survive");
    assert_eq!(good.logits.len(), 10);
    let bad_result = bad.recv().expect("bad response arrives");
    let err = bad_result.expect_err("bad request must error");
    assert!(err.contains("bad input"), "{err}");
    let m = coord.metrics();
    assert!(m.errors >= 1);
    coord.shutdown();
}

/// Native backend: the full serving path (dynamic batcher → engine
/// shards → digital twin) with zero artifacts, under concurrent load.
#[test]
fn native_shards_serve_concurrent_requests() {
    let coord = Coordinator::start(Config::native(3)).expect("native coordinator");
    let input_len = coord.model().input_len();
    let n_clients = 3;
    let per_client = 3;
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let coord = &coord;
            scope.spawn(move || {
                let mut rng = Rng::new(400 + c as u64);
                for _ in 0..per_client {
                    let resp = coord
                        .infer(InferRequest {
                            image: rng.i8_vec(input_len),
                        })
                        .expect("native inference");
                    assert_eq!(resp.logits.len(), 10);
                    assert!(resp.logits.iter().all(|x| x.is_finite()));
                    assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
                    assert!(resp.sim_energy_uj > 0.0);
                }
            });
        }
    });
    let m = coord.metrics();
    assert_eq!(m.requests, n_clients * per_client);
    assert_eq!(m.errors, 0);
    coord.shutdown();
}

/// Mixed-traffic fairness through the continuous-batching scheduler:
/// interleaved CNN image jobs and token-generation requests submitted
/// concurrently all complete (no starvation — a starved class would
/// hang the blocking `recv`s), with logits/outputs bit-identical to
/// isolated runs of each workload.
#[test]
fn continuous_mixed_traffic_fair_and_identical_to_isolated() {
    // Isolated references on one engine of the native shard geometry.
    let eng = Tcu::new(ArchKind::SystolicOs, 16, Variant::EntOurs).engine();
    let cnn = QuantCnn::tiny_native();
    let lm = QuantTransformer::tiny_native();
    let mut rng = Rng::new(0xFA1);
    let images: Vec<Vec<i8>> = (0..4).map(|_| rng.i8_vec(cnn.input_len())).collect();
    let prompts: Vec<Vec<u16>> = (0..4)
        .map(|s| (0..5 + s).map(|i| ((i * 13 + s * 7 + 1) % 64) as u16).collect())
        .collect();
    let image_refs: Vec<Vec<f32>> = images.iter().map(|img| cnn.forward(&eng, img)).collect();
    let token_refs: Vec<(Vec<f32>, Vec<u16>)> =
        prompts.iter().map(|p| lm.generate(&eng, p, 2)).collect();

    let coord = Coordinator::start(Config::continuous(2)).expect("continuous coordinator");
    std::thread::scope(|scope| {
        for (img, expect) in images.iter().zip(&image_refs) {
            let coord = &coord;
            scope.spawn(move || {
                let r = coord
                    .infer(InferRequest { image: img.clone() })
                    .expect("image through mixed traffic");
                assert_eq!(&r.logits, expect, "mixed traffic changed CNN logits");
            });
        }
        for (p, (want_logits, want_gen)) in prompts.iter().zip(&token_refs) {
            let coord = &coord;
            scope.spawn(move || {
                let r = coord
                    .infer_tokens(TokenRequest::generate(p.clone(), 2))
                    .expect("tokens through mixed traffic");
                assert_eq!(&r.logits, want_logits, "mixed traffic changed logits");
                assert_eq!(&r.generated, want_gen, "mixed traffic changed generation");
            });
        }
    });
    let m = coord.metrics();
    assert_eq!(m.requests, 8, "every request of both kinds completed");
    assert_eq!(m.errors, 0);
    assert_eq!(m.rejected, 0, "default admission bounds must not starve");
    assert!(m.tokens > 0);
    coord.shutdown();
}

/// Speculative decoding under an exact decode budget: whatever shape
/// the accepted windows take (an 8-wide oracle window accepts
/// everything it drafts), a request must emit *exactly* `max_new`
/// tokens — the drafting clamp keeps accepted drafts + the bonus token
/// inside the budget, with no clipping at resolve time — and the
/// stream must stay bit-identical to sequential decode for every
/// budget, including the no-speculation edges 1 and 2.
#[test]
fn speculation_respects_exact_decode_budget() {
    let model = QuantTransformer::tiny_native();
    let eng = Tcu::new(ArchKind::SystolicOs, 16, Variant::EntOurs).engine();
    let p: Vec<u16> = (0..6).map(|i| ((i * 5 + 3) % 64) as u16).collect();
    for max_new in 1..=5usize {
        let mut cfg = Config::continuous(2);
        cfg.twin_arch = ArchKind::SystolicOs;
        cfg.spec_decode = Some(true);
        cfg.spec_k = 8;
        cfg.draft = DraftKind::Oracle;
        let coord = Coordinator::start(cfg).expect("speculative coordinator");
        let r = coord
            .infer_tokens(TokenRequest::generate(p.clone(), max_new))
            .expect("generation");
        let m = coord.metrics();
        coord.shutdown();
        assert_eq!(
            r.generated.len(),
            max_new,
            "speculation must emit exactly the budget at max_new={max_new}"
        );
        let (want_logits, want_gen) = model.generate(&eng, &p, max_new);
        assert_eq!(r.generated, want_gen, "max_new={max_new}");
        assert_eq!(r.logits, want_logits, "max_new={max_new}");
        if max_new >= 3 {
            assert!(m.spec_rounds > 0, "budget {max_new} must speculate");
        } else {
            // One carried token (or none) past the prompt leaves no
            // room to draft: short budgets never enter a round.
            assert_eq!(m.spec_rounds, 0, "budget {max_new} must not speculate");
        }
    }
}

/// Admission deadlines keep expiring while in-flight sequences burn
/// steps on speculation rounds: stragglers queued behind a single
/// speculating decode slot exceed a 1 µs deadline and are rejected
/// with the standard error, while anything that was admitted resolves
/// bit-exactly.
#[test]
fn deadline_expiry_during_speculation_rejects_pending_stragglers() {
    let model = QuantTransformer::tiny_native();
    let eng = Tcu::new(ArchKind::SystolicOs, 16, Variant::EntOurs).engine();
    let p: Vec<u16> = (0..12).map(|i| ((i * 7 + 3) % 64) as u16).collect();
    let (want_logits, want_gen) = model.generate(&eng, &p, 4);
    let mut cfg = Config::continuous(1);
    cfg.twin_arch = ArchKind::SystolicOs;
    cfg.mode = ServeMode::Continuous(ContinuousPolicy {
        max_inflight: 1,
        deadline_us: 1,
        ..ContinuousPolicy::default()
    });
    cfg.spec_decode = Some(true);
    cfg.spec_k = 4;
    cfg.draft = DraftKind::Oracle;
    let coord = Coordinator::start(cfg).expect("speculative coordinator");
    let receivers: Vec<_> = (0..6)
        .map(|_| coord.submit_tokens(TokenRequest::generate(p.clone(), 4)))
        .collect();
    let mut done = 0u32;
    let mut expired = 0u32;
    for rx in receivers {
        match rx.recv().expect("response") {
            Ok(r) => {
                assert_eq!(r.generated, want_gen, "admitted request diverged");
                assert_eq!(r.logits, want_logits, "admitted request diverged");
                done += 1;
            }
            Err(e) => {
                assert!(e.contains("deadline exceeded"), "{e}");
                expired += 1;
            }
        }
    }
    assert_eq!(done + expired, 6);
    assert!(expired >= 2, "1 µs deadline must expire queued stragglers");
    assert_eq!(coord.metrics().errors, 0);
    coord.shutdown();
}

/// Queue-full admission while speculation is in flight: backpressure
/// is decided on pending + in-flight counts before any drafting
/// happens, so a 12-burst against queue cap 2 sheds load exactly as
/// without speculation — and every admitted request still returns the
/// sequential stream.
#[test]
fn backpressure_during_speculation_sheds_load_without_corruption() {
    let model = QuantTransformer::tiny_native();
    let eng = Tcu::new(ArchKind::SystolicOs, 16, Variant::EntOurs).engine();
    let p: Vec<u16> = (0..8).map(|i| ((i * 7 + 3) % 64) as u16).collect();
    let (want_logits, want_gen) = model.generate(&eng, &p, 3);
    let mut cfg = Config::continuous(1);
    cfg.twin_arch = ArchKind::SystolicOs;
    cfg.mode = ServeMode::Continuous(ContinuousPolicy {
        queue_cap: 2,
        max_inflight: 1,
        ..ContinuousPolicy::default()
    });
    cfg.spec_decode = Some(true);
    cfg.spec_k = 4;
    cfg.draft = DraftKind::Oracle;
    let coord = Coordinator::start(cfg).expect("speculative coordinator");
    let receivers: Vec<_> = (0..12)
        .map(|_| coord.submit_tokens(TokenRequest::generate(p.clone(), 3)))
        .collect();
    let mut ok = 0u32;
    let mut rejected = 0u32;
    for rx in receivers {
        match rx.recv().expect("response") {
            Ok(r) => {
                assert_eq!(r.generated, want_gen, "admitted request diverged");
                assert_eq!(r.logits, want_logits, "admitted request diverged");
                ok += 1;
            }
            Err(e) => {
                assert!(e.contains("backpressure"), "{e}");
                rejected += 1;
            }
        }
    }
    assert_eq!(ok + rejected, 12);
    assert!(rejected >= 1, "queue cap 2 must reject part of a 12-burst");
    assert!(ok >= 1, "admitted requests must still complete");
    let m = coord.metrics();
    assert_eq!(m.errors, 0);
    assert!(m.rejected >= rejected as u64);
    assert!(m.spec_rounds > 0, "admitted sequences speculated");
    coord.shutdown();
}

/// Window-mode fairness baseline: the same interleaving through the
/// window batcher also completes both classes — the schedulers differ
/// in latency shape, never in results or liveness.
#[test]
fn window_mixed_traffic_completes_both_classes() {
    let coord = Coordinator::start(Config::native(2)).expect("native coordinator");
    let input_len = coord.model().input_len();
    std::thread::scope(|scope| {
        for c in 0..2 {
            let coord = &coord;
            scope.spawn(move || {
                let mut rng = Rng::new(0x31 + c as u64);
                for _ in 0..2 {
                    coord
                        .infer(InferRequest {
                            image: rng.i8_vec(input_len),
                        })
                        .expect("image");
                    coord
                        .infer_tokens(TokenRequest::generate(vec![1, 2, 3], 1))
                        .expect("tokens");
                }
            });
        }
    });
    let m = coord.metrics();
    assert_eq!(m.requests, 8);
    assert_eq!(m.errors, 0);
    coord.shutdown();
}
