//! End-to-end serving integration: coordinator + dynamic batcher +
//! artifact runtime under concurrent load, including failure injection.
//! The artifact-backed tests gate on built artifacts (like
//! `cross_layer`); the native-backend tests always run — they serve
//! straight through the engine shards. The mixed-traffic tests at the
//! bottom cover the continuous-batching scheduler's fairness: CNN jobs
//! and token requests interleaved through one coordinator, with no
//! starvation and results identical to isolated runs.

use ent::arch::{ArchKind, Tcu};
use ent::coordinator::batcher::ContinuousPolicy;
use ent::coordinator::{
    Config, Coordinator, DraftKind, InferRequest, Job, JobMeta, Response, Spec, TokenRequest,
};
use ent::nn::forward::QuantCnn;
use ent::nn::transformer::QuantTransformer;
use ent::pe::Variant;
use ent::runtime::default_artifact_dir;
use ent::util::prng::Rng;

fn coordinator() -> Option<Coordinator> {
    if !default_artifact_dir().join("tinynet_b1.hlo.txt").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    Some(Coordinator::start(Config::default()).expect("coordinator up"))
}

#[test]
fn serves_concurrent_requests_with_batching() {
    let Some(coord) = coordinator() else { return };
    let input_len = coord.model().input_len();
    let n_clients = 4;
    let per_client = 8;
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let coord = &coord;
            scope.spawn(move || {
                let mut rng = Rng::new(100 + c as u64);
                for _ in 0..per_client {
                    let resp = coord
                        .infer(InferRequest {
                            image: rng.i8_vec(input_len),
                        })
                        .expect("inference");
                    assert_eq!(resp.logits.len(), 10);
                    assert!(resp.logits.iter().all(|x| x.is_finite()));
                    assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
                    assert!(resp.sim_energy_uj > 0.0);
                }
            });
        }
    });
    let m = coord.metrics();
    assert_eq!(m.requests, n_clients * per_client);
    assert_eq!(m.errors, 0);
    assert!(m.mean_batch >= 1.0);
    coord.shutdown();
}

#[test]
fn identical_inputs_get_identical_logits_across_batches() {
    let Some(coord) = coordinator() else { return };
    let input_len = coord.model().input_len();
    let mut rng = Rng::new(55);
    let img = rng.i8_vec(input_len);
    let first = coord
        .infer(InferRequest { image: img.clone() })
        .expect("first");
    // Concurrent duplicates force different batch groupings.
    std::thread::scope(|scope| {
        for _ in 0..6 {
            let coord = &coord;
            let img = img.clone();
            let expect = first.logits.clone();
            scope.spawn(move || {
                let r = coord.infer(InferRequest { image: img }).expect("dup");
                assert_eq!(r.logits, expect, "batching must not change results");
            });
        }
    });
    coord.shutdown();
}

#[test]
fn malformed_request_rejected_without_poisoning_the_batch() {
    let Some(coord) = coordinator() else { return };
    let input_len = coord.model().input_len();
    // Bad request (wrong length) concurrent with good ones.
    let bad = coord.submit(InferRequest {
        image: vec![0i8; 17],
    });
    let mut rng = Rng::new(77);
    let good = coord
        .infer(InferRequest {
            image: rng.i8_vec(input_len),
        })
        .expect("good request must survive");
    assert_eq!(good.logits.len(), 10);
    let bad_result = bad.recv().expect("bad response arrives");
    let err = bad_result.expect_err("bad request must error");
    assert!(err.contains("bad input"), "{err}");
    let m = coord.metrics();
    assert!(m.errors >= 1);
    coord.shutdown();
}

/// Native backend: the full serving path (dynamic batcher → engine
/// shards → digital twin) with zero artifacts, under concurrent load.
#[test]
fn native_shards_serve_concurrent_requests() {
    let cfg = Config::builder().native(3).build().expect("config");
    let coord = Coordinator::start(cfg).expect("native coordinator");
    let input_len = coord.model().input_len();
    let n_clients = 3;
    let per_client = 3;
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let coord = &coord;
            scope.spawn(move || {
                let mut rng = Rng::new(400 + c as u64);
                for _ in 0..per_client {
                    let resp = coord
                        .infer(InferRequest {
                            image: rng.i8_vec(input_len),
                        })
                        .expect("native inference");
                    assert_eq!(resp.logits.len(), 10);
                    assert!(resp.logits.iter().all(|x| x.is_finite()));
                    assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
                    assert!(resp.sim_energy_uj > 0.0);
                }
            });
        }
    });
    let m = coord.metrics();
    assert_eq!(m.requests, n_clients * per_client);
    assert_eq!(m.errors, 0);
    coord.shutdown();
}

/// Mixed-traffic fairness through the continuous-batching scheduler:
/// interleaved CNN image jobs and token-generation requests submitted
/// concurrently all complete (no starvation — a starved class would
/// hang the blocking `recv`s), with logits/outputs bit-identical to
/// isolated runs of each workload.
#[test]
fn continuous_mixed_traffic_fair_and_identical_to_isolated() {
    // Isolated references on one engine of the native shard geometry.
    let eng = Tcu::new(ArchKind::SystolicOs, 16, Variant::EntOurs).engine();
    let cnn = QuantCnn::tiny_native();
    let lm = QuantTransformer::tiny_native();
    let mut rng = Rng::new(0xFA1);
    let images: Vec<Vec<i8>> = (0..4).map(|_| rng.i8_vec(cnn.input_len())).collect();
    let prompts: Vec<Vec<u16>> = (0..4)
        .map(|s| (0..5 + s).map(|i| ((i * 13 + s * 7 + 1) % 64) as u16).collect())
        .collect();
    let image_refs: Vec<Vec<f32>> = images.iter().map(|img| cnn.forward(&eng, img)).collect();
    let token_refs: Vec<(Vec<f32>, Vec<u16>)> =
        prompts.iter().map(|p| lm.generate(&eng, p, 2)).collect();

    let cfg = Config::builder().continuous(2).build().expect("config");
    let coord = Coordinator::start(cfg).expect("continuous coordinator");
    std::thread::scope(|scope| {
        for (img, expect) in images.iter().zip(&image_refs) {
            let coord = &coord;
            scope.spawn(move || {
                let r = coord
                    .infer(InferRequest { image: img.clone() })
                    .expect("image through mixed traffic");
                assert_eq!(&r.logits, expect, "mixed traffic changed CNN logits");
            });
        }
        for (p, (want_logits, want_gen)) in prompts.iter().zip(&token_refs) {
            let coord = &coord;
            scope.spawn(move || {
                let r = coord
                    .infer_tokens(TokenRequest::generate(p.clone(), 2))
                    .expect("tokens through mixed traffic");
                assert_eq!(&r.logits, want_logits, "mixed traffic changed logits");
                assert_eq!(&r.generated, want_gen, "mixed traffic changed generation");
            });
        }
    });
    let m = coord.metrics();
    assert_eq!(m.requests, 8, "every request of both kinds completed");
    assert_eq!(m.errors, 0);
    assert_eq!(m.rejected, 0, "default admission bounds must not starve");
    assert!(m.tokens > 0);
    coord.shutdown();
}

/// Speculative decoding under an exact decode budget: whatever shape
/// the accepted windows take (an 8-wide oracle window accepts
/// everything it drafts), a request must emit *exactly* `max_new`
/// tokens — the drafting clamp keeps accepted drafts + the bonus token
/// inside the budget, with no clipping at resolve time — and the
/// stream must stay bit-identical to sequential decode for every
/// budget, including the no-speculation edges 1 and 2.
#[test]
fn speculation_respects_exact_decode_budget() {
    let model = QuantTransformer::tiny_native();
    let eng = Tcu::new(ArchKind::SystolicOs, 16, Variant::EntOurs).engine();
    let p: Vec<u16> = (0..6).map(|i| ((i * 5 + 3) % 64) as u16).collect();
    for max_new in 1..=5usize {
        let cfg = Config::builder()
            .continuous(2)
            .twin(ArchKind::SystolicOs, Variant::EntOurs)
            .speculation(Spec::On { k: 8, draft: DraftKind::Oracle })
            .build()
            .expect("config");
        let coord = Coordinator::start(cfg).expect("speculative coordinator");
        let r = coord
            .infer_tokens(TokenRequest::generate(p.clone(), max_new))
            .expect("generation");
        let m = coord.metrics();
        coord.shutdown();
        assert_eq!(
            r.generated.len(),
            max_new,
            "speculation must emit exactly the budget at max_new={max_new}"
        );
        let (want_logits, want_gen) = model.generate(&eng, &p, max_new);
        assert_eq!(r.generated, want_gen, "max_new={max_new}");
        assert_eq!(r.logits, want_logits, "max_new={max_new}");
        if max_new >= 3 {
            assert!(m.spec_rounds > 0, "budget {max_new} must speculate");
        } else {
            // One carried token (or none) past the prompt leaves no
            // room to draft: short budgets never enter a round.
            assert_eq!(m.spec_rounds, 0, "budget {max_new} must not speculate");
        }
    }
}

/// Admission deadlines keep expiring while in-flight sequences burn
/// steps on speculation rounds: stragglers queued behind a single
/// speculating decode slot exceed a 1 µs deadline and are rejected
/// with the standard error, while anything that was admitted resolves
/// bit-exactly.
#[test]
fn deadline_expiry_during_speculation_rejects_pending_stragglers() {
    let model = QuantTransformer::tiny_native();
    let eng = Tcu::new(ArchKind::SystolicOs, 16, Variant::EntOurs).engine();
    let p: Vec<u16> = (0..12).map(|i| ((i * 7 + 3) % 64) as u16).collect();
    let (want_logits, want_gen) = model.generate(&eng, &p, 4);
    let cfg = Config::builder()
        .continuous(1)
        .twin(ArchKind::SystolicOs, Variant::EntOurs)
        .policy(ContinuousPolicy {
            max_inflight: 1,
            deadline_us: 1,
            ..ContinuousPolicy::default()
        })
        .speculation(Spec::On { k: 4, draft: DraftKind::Oracle })
        .build()
        .expect("config");
    let coord = Coordinator::start(cfg).expect("speculative coordinator");
    let receivers: Vec<_> = (0..6)
        .map(|_| coord.submit_tokens(TokenRequest::generate(p.clone(), 4)))
        .collect();
    let mut done = 0u32;
    let mut expired = 0u32;
    for rx in receivers {
        match rx.recv().expect("response") {
            Ok(r) => {
                assert_eq!(r.generated, want_gen, "admitted request diverged");
                assert_eq!(r.logits, want_logits, "admitted request diverged");
                done += 1;
            }
            Err(e) => {
                assert!(e.contains("deadline exceeded"), "{e}");
                expired += 1;
            }
        }
    }
    assert_eq!(done + expired, 6);
    assert!(expired >= 2, "1 µs deadline must expire queued stragglers");
    assert_eq!(coord.metrics().errors, 0);
    coord.shutdown();
}

/// Queue-full admission while speculation is in flight: backpressure
/// is decided on pending + in-flight counts before any drafting
/// happens, so a 12-burst against queue cap 2 sheds load exactly as
/// without speculation — and every admitted request still returns the
/// sequential stream.
#[test]
fn backpressure_during_speculation_sheds_load_without_corruption() {
    let model = QuantTransformer::tiny_native();
    let eng = Tcu::new(ArchKind::SystolicOs, 16, Variant::EntOurs).engine();
    let p: Vec<u16> = (0..8).map(|i| ((i * 7 + 3) % 64) as u16).collect();
    let (want_logits, want_gen) = model.generate(&eng, &p, 3);
    let cfg = Config::builder()
        .continuous(1)
        .twin(ArchKind::SystolicOs, Variant::EntOurs)
        .policy(ContinuousPolicy {
            queue_cap: 2,
            max_inflight: 1,
            ..ContinuousPolicy::default()
        })
        .speculation(Spec::On { k: 4, draft: DraftKind::Oracle })
        .build()
        .expect("config");
    let coord = Coordinator::start(cfg).expect("speculative coordinator");
    let receivers: Vec<_> = (0..12)
        .map(|_| coord.submit_tokens(TokenRequest::generate(p.clone(), 3)))
        .collect();
    let mut ok = 0u32;
    let mut rejected = 0u32;
    for rx in receivers {
        match rx.recv().expect("response") {
            Ok(r) => {
                assert_eq!(r.generated, want_gen, "admitted request diverged");
                assert_eq!(r.logits, want_logits, "admitted request diverged");
                ok += 1;
            }
            Err(e) => {
                assert!(e.contains("backpressure"), "{e}");
                rejected += 1;
            }
        }
    }
    assert_eq!(ok + rejected, 12);
    assert!(rejected >= 1, "queue cap 2 must reject part of a 12-burst");
    assert!(ok >= 1, "admitted requests must still complete");
    let m = coord.metrics();
    assert_eq!(m.errors, 0);
    assert!(m.rejected >= rejected as u64);
    assert!(m.spec_rounds > 0, "admitted sequences speculated");
    coord.shutdown();
}

/// Weighted-fair admission: a tenant flooding the queue is capped at
/// its proportional share, so an equal-weight tenant arriving behind
/// the flood is never rejected. With weights (1, 1) and queue cap 12,
/// each tenant's share cap is 6 — the flooder's 20-burst sheds its
/// overflow with the weighted-share error while all four requests of
/// the second tenant complete.
#[test]
fn flooding_tenant_cannot_starve_weighted_peer() {
    let cfg = Config::builder()
        .continuous(1)
        .policy(ContinuousPolicy {
            max_inflight: 1,
            queue_cap: 12,
            ..ContinuousPolicy::default()
        })
        .tenant_weight(1, 1)
        .tenant_weight(2, 1)
        .build()
        .expect("config");
    let coord = Coordinator::start(cfg).expect("weighted coordinator");
    let p: Vec<u16> = (0..8).map(|i| ((i * 7 + 3) % 64) as u16).collect();
    let meta = |tenant| JobMeta { tenant, session: None };
    let flood: Vec<_> = (0..20)
        .map(|_| coord.submit_job(Job::Tokens(TokenRequest::generate(p.clone(), 1)), meta(1)))
        .collect();
    let victim: Vec<_> = (0..4)
        .map(|_| coord.submit_job(Job::Tokens(TokenRequest::generate(p.clone(), 1)), meta(2)))
        .collect();
    let mut flood_ok = 0u32;
    let mut flood_shed = 0u32;
    for rx in flood {
        match rx.recv().expect("flood response") {
            Ok(_) => flood_ok += 1,
            Err(e) => {
                assert!(
                    e.contains("backpressure") && e.contains("weighted share"),
                    "{e}"
                );
                flood_shed += 1;
            }
        }
    }
    for rx in victim {
        let r = rx.recv().expect("victim response");
        assert!(r.is_ok(), "weighted tenant must not starve: {r:?}");
    }
    assert_eq!(flood_ok + flood_shed, 20);
    assert!(
        flood_shed >= 10,
        "a 20-burst against share cap 6 must shed most of the flood \
         (shed {flood_shed})"
    );
    let m = coord.metrics();
    assert_eq!(m.errors, 0);
    assert!(m.rejected >= flood_shed as u64);
    coord.shutdown();
}

/// Session affinity survives the prefill→decode handoff: under pooled
/// serving, equal session keys pin to the same decode-pool slot and
/// different sessions spread across slots — the response's
/// `decode_slot` exposes the pinning.
#[test]
fn session_affinity_survives_pool_handoff() {
    let cfg = Config::builder().pools(1, 2).build().expect("config");
    let coord = Coordinator::start(cfg).expect("pooled coordinator");
    let p: Vec<u16> = (0..6).map(|i| ((i * 5 + 2) % 64) as u16).collect();
    let run = |session: u64| {
        let meta = JobMeta {
            tenant: 0,
            session: Some(session),
        };
        match coord
            .infer_job(Job::Tokens(TokenRequest::generate(p.clone(), 2)), meta)
            .expect("pooled token job")
        {
            Response::Tokens(r) => {
                assert_eq!(r.generated.len(), 2);
                assert!(r.ttft_us <= r.latency_us);
                r.decode_slot
            }
            Response::Image(_) => panic!("token job answered with an image"),
        }
    };
    let a1 = run(42);
    let a2 = run(42);
    let b = run(43);
    assert_eq!(a1, a2, "same session must pin to the same decode slot");
    assert_ne!(a1, b, "sessions 42/43 must map to different slots of 2");
    let m = coord.metrics();
    assert!(m.handoffs >= 3, "every request hands off once");
    assert_eq!(m.errors, 0);
    coord.shutdown();
}

/// A deadline that expires between prefill completion and decode
/// promotion rolls the sequence back mid-handoff: the request is
/// rejected with the handoff-deadline error, nothing is promoted to the
/// decode pool, and the coordinator stays healthy. Four 48-token
/// prompts at prefill chunk 1 on a single prefill shard take far longer
/// than the 50 ms deadline, so all four park in handoff already
/// expired.
#[test]
fn deadline_expiry_mid_handoff_rolls_back_cleanly() {
    let cfg = Config::builder()
        .pools(1, 1)
        .policy(ContinuousPolicy {
            max_inflight: 4,
            prefill_chunk: 1,
            deadline_us: 50_000,
            ..ContinuousPolicy::default()
        })
        .build()
        .expect("config");
    let coord = Coordinator::start(cfg).expect("pooled coordinator");
    let p: Vec<u16> = (0..48).map(|i| ((i * 11 + 5) % 64) as u16).collect();
    let receivers: Vec<_> = (0..4)
        .map(|_| {
            coord.submit_job(
                Job::Tokens(TokenRequest::generate(p.clone(), 2)),
                JobMeta::default(),
            )
        })
        .collect();
    let mut expired_in_handoff = 0u32;
    for rx in receivers {
        match rx.recv().expect("response") {
            Err(e) => {
                assert!(e.contains("deadline exceeded during pool handoff"), "{e}");
                expired_in_handoff += 1;
            }
            Ok(_) => {
                // A machine fast enough to prefill 4×48 chunked tokens
                // inside 50 ms would legitimately complete the request;
                // bit-level engines are orders of magnitude slower.
                panic!("48-token chunked prefill finished inside a 50 ms deadline");
            }
        }
    }
    assert_eq!(expired_in_handoff, 4);
    let m = coord.metrics();
    assert_eq!(m.handoffs, 0, "expired sequences must never promote");
    assert_eq!(m.rejected, 4);
    assert_eq!(m.errors, 0);
    coord.shutdown();
}

/// Window-mode fairness baseline: the same interleaving through the
/// window batcher also completes both classes — the schedulers differ
/// in latency shape, never in results or liveness.
#[test]
fn window_mixed_traffic_completes_both_classes() {
    let cfg = Config::builder().native(2).build().expect("config");
    let coord = Coordinator::start(cfg).expect("native coordinator");
    let input_len = coord.model().input_len();
    std::thread::scope(|scope| {
        for c in 0..2 {
            let coord = &coord;
            scope.spawn(move || {
                let mut rng = Rng::new(0x31 + c as u64);
                for _ in 0..2 {
                    coord
                        .infer(InferRequest {
                            image: rng.i8_vec(input_len),
                        })
                        .expect("image");
                    coord
                        .infer_tokens(TokenRequest::generate(vec![1, 2, 3], 1))
                        .expect("tokens");
                }
            });
        }
    });
    let m = coord.metrics();
    assert_eq!(m.requests, 8);
    assert_eq!(m.errors, 0);
    coord.shutdown();
}
