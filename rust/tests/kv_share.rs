//! Equivalence + accounting suite for **cross-request prefix sharing**
//! (`nn::kvpool::KvPool` — paged K/V blocks behind a radix prefix
//! index): a warm-prefix run adopting pool-resident blocks must be
//! bit-identical to a cold run across the full 5-architecture ×
//! 4-variant grid, copy-on-write forks must match their solo runs, LRU
//! eviction under a one-entry budget must never invalidate blocks a
//! live sequence holds, and — the acceptance criterion — resident rows
//! must charge **0** encode events and **0** prefill MACs through the
//! planner and the SoC energy walk.

use ent::arch::{ArchKind, Tcu, ALL_ARCHS};
use ent::coordinator::{Config, Coordinator, TokenRequest};
use ent::nn::kvpool::{shareable_rows, KvPool, BLOCK_ROWS};
use ent::nn::transformer::{QuantTransformer, TransformerSpec};
use ent::pe::Variant;
use ent::sim::{GemmShape, TilePlan};
use ent::soc::energy::{frame_energy_with, EnergyOpts};
use ent::soc::Soc;

fn prompt(n: usize) -> Vec<u16> {
    (0..n).map(|i| ((i * 7 + 3) % 64) as u16).collect()
}

/// The headline equivalence: a warm run that adopts the donor's
/// pool-resident prefix blocks and feeds only the tail produces
/// bit-identical logits and greedy tokens to a cold sequential run, on
/// every architecture × variant (non-EN-T engines exercise the raw-row
/// fallback; EN-T(Ours) additionally reuses the adopted code sidecars).
#[test]
fn warm_prefix_decode_bit_identical_across_grid() {
    let model = QuantTransformer::tiny_native().with_kv_prepack(true);
    let toks = prompt(9);
    for arch in ALL_ARCHS {
        let size = if arch == ArchKind::Cube3d { 4 } else { 8 };
        for variant in Variant::ALL {
            let eng = Tcu::new(arch, size, variant).engine();
            let tag = format!("{} {}", arch.name(), variant.name());
            // Cold reference run.
            let (want_logits, want_toks) = model.generate(&eng, &toks, 3);
            // Donor request: full prefill, then publish to the pool.
            let pool = KvPool::new(1 << 20);
            let mut donor = model.empty_caches();
            model.prefill(&eng, &toks, &mut donor);
            pool.insert(&toks, &donor);
            // Warm request: adopt the resident block, feed the tail.
            let mut caches = model.empty_caches();
            let resident = pool.attach(&toks, &mut caches);
            assert_eq!(resident, shareable_rows(toks.len()), "{tag}");
            assert_eq!(resident, BLOCK_ROWS, "9-token prompt shares one block");
            let mut logits = model.prefill(&eng, &toks[resident..], &mut caches);
            let mut got_toks = Vec::new();
            for _ in 0..3 {
                let next = QuantTransformer::argmax(&logits);
                got_toks.push(next);
                logits = model.decode(&eng, next, &mut caches);
            }
            assert_eq!(logits, want_logits, "warm logits diverged: {tag}");
            assert_eq!(got_toks, want_toks, "warm tokens diverged: {tag}");
        }
    }
}

/// Copy-on-write fork: two requests share the first block of their
/// prompts and diverge after it. Each warm run must match its own solo
/// cold run — the shared physical block feeds both without either
/// request's tail contaminating the other.
#[test]
fn cow_fork_mid_prefix_matches_solo_runs() {
    let model = QuantTransformer::tiny_native().with_kv_prepack(true);
    let eng = Tcu::new(ArchKind::SystolicOs, 8, Variant::EntOurs).engine();
    let a_toks = prompt(12);
    let mut b_toks = prompt(12);
    for t in &mut b_toks[9..] {
        *t = (*t + 11) % 64; // fork after the shared first block
    }
    assert_eq!(a_toks[..BLOCK_ROWS], b_toks[..BLOCK_ROWS]);
    let (a_solo_logits, a_solo_toks) = model.generate(&eng, &a_toks, 2);
    let (b_solo_logits, b_solo_toks) = model.generate(&eng, &b_toks, 2);
    assert_ne!(a_solo_logits, b_solo_logits, "fork must actually diverge");

    // Request A runs cold and publishes its prefix.
    let pool = KvPool::new(1 << 20);
    let mut a_caches = model.empty_caches();
    let mut a_logits = model.prefill(&eng, &a_toks, &mut a_caches);
    pool.insert(&a_toks, &a_caches);
    // Request B warm-hits A's first block despite the diverged tail
    // (the radix walk shares exactly the common block-aligned prefix).
    let mut b_caches = model.empty_caches();
    let resident = pool.attach(&b_toks, &mut b_caches);
    assert_eq!(resident, BLOCK_ROWS);
    let mut b_logits = model.prefill(&eng, &b_toks[resident..], &mut b_caches);
    // Both decode to completion; outputs must equal the solo runs.
    let mut a_got = Vec::new();
    let mut b_got = Vec::new();
    for _ in 0..2 {
        let a_next = QuantTransformer::argmax(&a_logits);
        a_got.push(a_next);
        a_logits = model.decode(&eng, a_next, &mut a_caches);
        let b_next = QuantTransformer::argmax(&b_logits);
        b_got.push(b_next);
        b_logits = model.decode(&eng, b_next, &mut b_caches);
    }
    assert_eq!((a_logits, a_got), (a_solo_logits, a_solo_toks));
    assert_eq!((b_logits, b_got), (b_solo_logits, b_solo_toks));
}

/// LRU eviction under a one-entry budget: inserting a second prefix
/// evicts the first (refcount-safe — the pool drops its reference, the
/// donor's caches keep theirs), the evicted prefix misses on re-attach,
/// and a sequence still holding evicted blocks decodes bit-identically.
#[test]
fn one_entry_budget_evicts_lru_without_invalidating_live_sequences() {
    let model = QuantTransformer::tiny_native().with_kv_prepack(true);
    let eng = Tcu::new(ArchKind::Matrix2d, 8, Variant::EntOurs).engine();
    let a_toks = prompt(9);
    let b_toks: Vec<u16> = a_toks.iter().map(|&t| (t + 29) % 64).collect();

    // Probe one entry's footprint with an unconstrained pool.
    let probe = KvPool::new(1 << 20);
    let mut donor_a = model.empty_caches();
    model.prefill(&eng, &a_toks, &mut donor_a);
    probe.insert(&a_toks, &donor_a);
    let entry_bytes = probe.stats().bytes;
    assert!(entry_bytes > 0);

    // A budget of exactly one entry: the second insert evicts the first.
    let pool = KvPool::new(entry_bytes);
    pool.insert(&a_toks, &donor_a);
    assert_eq!(pool.stats().entries, 1);
    // Warm-attach A before it gets evicted — this sequence holds Arcs.
    let mut warm_a = model.empty_caches();
    let resident = pool.attach(&a_toks, &mut warm_a);
    assert_eq!(resident, BLOCK_ROWS);
    let mut donor_b = model.empty_caches();
    model.prefill(&eng, &b_toks, &mut donor_b);
    pool.insert(&b_toks, &donor_b);
    let st = pool.stats();
    assert_eq!(st.entries, 1, "one-entry budget must hold one entry");
    assert!(st.evictions >= 1, "inserting B must evict A: {st:?}");
    assert!(st.bytes <= entry_bytes);
    // A is gone from the index; B is resident.
    let mut probe_a = model.empty_caches();
    assert_eq!(pool.attach(&a_toks, &mut probe_a), 0, "evicted prefix must miss");
    let mut probe_b = model.empty_caches();
    assert_eq!(pool.attach(&b_toks, &mut probe_b), BLOCK_ROWS);
    // The live warm sequence still owns the evicted blocks: finishing
    // its prefill + decode matches the cold run exactly.
    let (want_logits, want_toks) = model.generate(&eng, &a_toks, 2);
    let mut logits = model.prefill(&eng, &a_toks[resident..], &mut warm_a);
    let mut got = Vec::new();
    for _ in 0..2 {
        let next = QuantTransformer::argmax(&logits);
        got.push(next);
        logits = model.decode(&eng, next, &mut warm_a);
    }
    assert_eq!((logits, got), (want_logits, want_toks));
}

/// The acceptance criterion, planner-verified: an attention GEMM whose
/// history is fully pool-resident charges **0** encode events on
/// EN-T(Ours); partial residency charges exactly the non-resident rows;
/// non-consuming variants are inert.
#[test]
fn warm_prefix_admission_charges_zero_encodes_for_resident_rows() {
    let tcu = Tcu::new(ArchKind::SystolicOs, 8, Variant::EntOurs);
    let plan = TilePlan::new(&tcu, GemmShape::new(1, 8, 17));
    let warm = plan.stats_kv_shared(17);
    assert_eq!(warm.encodes, 0, "resident rows must charge 0 encode events");
    assert_eq!(warm.activation_encodes, 0);
    assert_eq!(warm.weight_encodes, 0);
    // The non-encode event counts never move.
    let plain = plan.stats_attention();
    assert_eq!(warm.cycles, plain.cycles);
    assert_eq!(warm.a_reads, plain.a_reads);
    assert_eq!(warm.b_reads, plain.b_reads);
    for v in Variant::non_code_consuming() {
        let t = Tcu::new(ArchKind::SystolicOs, 8, v);
        let p = TilePlan::new(&t, GemmShape::new(1, 8, 17));
        assert_eq!(p.stats_kv_shared(17).encodes, p.stats_attention().encodes);
    }
}

/// The same criterion through the SoC energy walk: a warm prefill's
/// resident rows contribute 0 prefill MACs and 0 encode events — the
/// encode total scales with the fresh rows only, and a fully warm
/// admission prices exactly like one decode step.
#[test]
fn warm_prefill_energy_scales_with_fresh_rows_only() {
    let spec = TransformerSpec::tiny();
    let soc = Soc::paper_config(ArchKind::SystolicOs, Variant::EntOurs);
    let opts = EnergyOpts {
        encode_cache: true,
        kv_prepack: true,
    };
    let cold = frame_energy_with(&soc, &spec.prefill_network(12), opts).0;
    let warm = frame_energy_with(&soc, &spec.warm_prefill_network(12, 8), opts).0;
    let per_row = 2 * (spec.d_model * spec.layers) as u64;
    assert_eq!(cold.encodes, 12 * per_row);
    assert_eq!(warm.encodes, (12 - 8) * per_row, "resident rows must encode nothing");
    assert_eq!(warm.weight_encodes, 0);
    assert!(warm.macs < cold.macs, "resident rows must add no prefill MACs");
    // Fully warm admission ≡ one decode step at the same context.
    let full = frame_energy_with(&soc, &spec.warm_prefill_network(12, 11), opts).0;
    let dec = frame_energy_with(&soc, &spec.decode_network(12), opts).0;
    assert_eq!(full.macs, dec.macs);
    assert_eq!(full.encodes, dec.encodes);
    assert_eq!(full.total_pj(), dec.total_pj());
}

/// End-to-end through the continuous scheduler: prefix sharing on (the
/// default) serves bit-identical logits/tokens to sharing off, repeated
/// prompts warm-hit the pool, and the pool counters ride the metrics
/// snapshot (absent when sharing is off).
#[test]
fn continuous_serving_prefix_share_matches_off_and_counters_surface() {
    let on_cfg = Config::builder().continuous(2).build().expect("config");
    let on = Coordinator::start(on_cfg).expect("share-on coordinator");
    let off_cfg = Config::builder()
        .continuous(2)
        .prefix_share(false)
        .build()
        .expect("config");
    let off = Coordinator::start(off_cfg).expect("share-off coordinator");

    let req = || TokenRequest::generate(prompt(12), 2);
    for round in 0..3 {
        let a = on.infer_tokens(req()).expect("share-on serve");
        let b = off.infer_tokens(req()).expect("share-off serve");
        assert_eq!(a.logits, b.logits, "prefix sharing changed logits (round {round})");
        assert_eq!(a.generated, b.generated, "round {round}");
    }
    let ps = on.metrics().kv_pool.expect("pool counters must surface");
    assert!(ps.insertions >= 1, "{ps:?}");
    assert!(
        ps.hit_rows >= 2 * BLOCK_ROWS as u64,
        "repeated prompts must adopt resident blocks: {ps:?}"
    );
    assert!(ps.bytes > 0, "resident-bytes gauge must be live: {ps:?}");
    assert!(ps.hit_rate() > 0.0);
    assert!(on.metrics().kv_pool.unwrap().budget_bytes > 0);
    let m_off = off.metrics();
    assert!(m_off.kv_pool.is_none(), "share-off must not attach a pool");
    on.shutdown();
    off.shutdown();
}
