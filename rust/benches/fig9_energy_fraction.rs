//! Bench target for **Fig 9** — normalized SoC energy fractions under
//! the baseline TCU, per network, for each of three representative
//! architecture panels (the paper's (a)(b)(c) sub-figures), plus the
//! frame-simulation throughput.

use ent::arch::ArchKind;
use ent::nn::zoo;
use ent::pe::Variant;
use ent::soc::{energy, Soc};
use ent::util::bench::{black_box, header, Suite};

fn main() {
    header("Fig 9 — SoC energy fraction (baseline TCU)");
    for arch in [ArchKind::SystolicOs, ArchKind::Matrix2d, ArchKind::Cube3d] {
        print!("{}", ent::report::fig9(arch));
    }

    header("frame-energy simulation throughput");
    let mut suite = Suite::new();
    let soc = Soc::paper_config(ArchKind::SystolicOs, Variant::Baseline);
    let resnet50 = zoo::by_name("resnet50").unwrap();
    let r = suite.bench("frame_energy_resnet50", || {
        black_box(energy::frame_energy(&soc, &resnet50).0.total_pj());
    });
    let macs = resnet50.total_macs() as f64;
    println!(
        "simulator rate: {:.0} frames/s ≈ {:.1} G MAC-events modelled per second",
        r.throughput(),
        macs * r.throughput() / 1e9
    );
}
