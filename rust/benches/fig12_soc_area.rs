//! Bench target for **Fig 12** — area efficiency of the SoC: TCU-level
//! improvement vs SoC-level (diluted by SRAM/controller/SIMD).

use ent::util::bench::header;

fn main() {
    header("Fig 12 — SoC area efficiency");
    print!("{}", ent::report::fig12());
}
