//! Transformer workload benchmark — wall-clock prefill and KV-cache
//! decode throughput of the bit-accurate int8 encoder stack on every
//! architecture × variant (256 GOPS scale), plus the decode-vs-recompute
//! contrast that motivates the KV cache.
//!
//! Emits `BENCH_transformer.json` at the workspace root — tokens/s and
//! ns/MAC per arch × variant, prefill vs plain decode vs decode through
//! the append-only prepacked KV cache (`decode_kvpp` rows) — so the
//! transformer perf trajectory is tracked across PRs alongside
//! `BENCH_hotpath.json`.

use ent::arch::{ArchKind, Scale, Tcu, ALL_ARCHS};
use ent::nn::transformer::QuantTransformer;
use ent::pe::Variant;
use ent::util::bench::{black_box, header, BenchResult, Suite};
use ent::util::json::Json;

/// Prompt length for the prefill phase (and the decode context).
const SEQ: usize = 16;

fn main() {
    header("transformer workload performance");
    let mut suite = Suite::new();
    let model = QuantTransformer::tiny_native();
    let model_pp = QuantTransformer::tiny_native().with_kv_prepack(true);
    let spec = model.spec;
    let prompt: Vec<u16> = (0..SEQ).map(|i| ((i * 11 + 2) % spec.vocab) as u16).collect();
    let prefill_macs = spec.prefill_network(SEQ).total_macs() as f64;
    let decode_macs = spec.decode_network(SEQ + 1).total_macs() as f64;
    let recompute_macs = spec.prefill_network(SEQ + 1).total_macs() as f64;
    println!(
        "  model: {}L d_model {} heads {} d_ff {}  |  prefill({SEQ}) {} MACs, decode {} MACs \
         (recompute would be {} — KV cache saves {:.1}%)",
        spec.layers,
        spec.d_model,
        spec.heads,
        spec.d_ff,
        prefill_macs,
        decode_macs,
        recompute_macs,
        (1.0 - decode_macs / recompute_macs) * 100.0
    );

    let mut json_rows: Vec<Json> = Vec::new();
    for arch in ALL_ARCHS {
        for variant in Variant::ALL {
            let size = arch.size_for_scale(Scale::Gops256);
            let eng = Tcu::new(arch, size, variant).engine();

            // Prefill: the whole prompt from a cold cache per iteration.
            let name = format!("prefill{SEQ}_{}_{}", arch.short_name(), variant.name());
            let r = suite.bench(&name, || {
                let mut caches = model.empty_caches();
                black_box(model.prefill(&eng, &prompt, &mut caches));
            });
            json_rows.push(row(arch, variant, "prefill", SEQ, prefill_macs, r));

            // Decode: one token against a warm cache, rewound each
            // iteration so every step attends over the same context.
            let mut caches = model.empty_caches();
            model.prefill(&eng, &prompt, &mut caches);
            let name = format!("decode_{}_{}", arch.short_name(), variant.name());
            let r = suite.bench(&name, || {
                for c in caches.iter_mut() {
                    c.truncate(SEQ);
                }
                black_box(model.decode(&eng, 7, &mut caches));
            });
            json_rows.push(row(arch, variant, "decode", 1, decode_macs, r));

            // Decode through the append-only prepacked KV cache: the
            // truncate invalidates exactly one position, so each
            // iteration re-encodes only the appended token's K/V rows
            // while the history's codes are reused (non-EN-T variants
            // exercise the transparent fallback).
            let mut caches = model_pp.empty_caches();
            model_pp.prefill(&eng, &prompt, &mut caches);
            let name = format!("decode_kvpp_{}_{}", arch.short_name(), variant.name());
            let r = suite.bench(&name, || {
                for c in caches.iter_mut() {
                    c.truncate(SEQ);
                }
                black_box(model_pp.decode(&eng, 7, &mut caches));
            });
            json_rows.push(row(arch, variant, "decode_kvpp", 1, decode_macs, r));
        }
    }

    let out = Json::obj(vec![
        ("bench", Json::str("transformer_perf")),
        ("unit", Json::str("tokens_per_s / ns_per_mac")),
        ("seq", Json::num(SEQ as f64)),
        ("kv_mac_saving", Json::num(1.0 - decode_macs / recompute_macs)),
        ("results", Json::arr(json_rows)),
    ]);
    // Cargo runs benches with cwd = the package dir (rust/); anchor the
    // output at the workspace root so CI finds it deterministically.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_transformer.json");
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
}

fn row(
    arch: ArchKind,
    variant: Variant,
    phase: &str,
    tokens_per_iter: usize,
    macs: f64,
    r: &BenchResult,
) -> Json {
    Json::obj(vec![
        ("name", Json::str(r.name.clone())),
        ("arch", Json::str(arch.short_name())),
        ("variant", Json::str(variant.name())),
        ("phase", Json::str(phase)),
        ("tokens_per_s", Json::num(tokens_per_iter as f64 * r.throughput())),
        ("ns_per_iter", Json::num(r.ns_per_iter.mean)),
        ("ns_per_mac", Json::num(r.ns_per_iter.mean / macs)),
    ])
}
