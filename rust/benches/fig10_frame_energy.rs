//! Bench target for **Fig 10** — single-frame SoC inference energy for
//! the eight networks × five architectures × three variants.

use ent::util::bench::header;

fn main() {
    header("Fig 10 — single-frame SoC energy");
    print!("{}", ent::report::fig10());
}
