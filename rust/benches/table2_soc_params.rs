//! Bench target for **Table 2 / Fig 8** — the benchmark SoC's component
//! parameters, assembled from our calibrated models and compared against
//! the paper's published values.

use ent::util::bench::header;

fn main() {
    header("Table 2 — SoC benchmark parameters");
    print!("{}", ent::report::table2());
    println!(
        "\npaper Table 2: GB 256KB 614400 µm² (r 0.0205 W / w 0.04515 W); \
         A/W buffer 64KB 153600 µm² (r 0.0146 / w 0.0322); \
         SIMD 32×TF32 126481 µm² 0.0951 W; \
         Controller×2 83679 µm² 0.0632 W; Encoder×32 1895.36 µm²"
    );
}
