//! Bench target for **Fig 11** — SoC inference-energy reduction ratio of
//! EN-T(Ours) vs baseline per architecture, with the per-network detail
//! the paper plots.

use ent::arch::ALL_ARCHS;
use ent::nn::zoo;
use ent::soc::energy;
use ent::util::bench::header;
use ent::util::table::{pct, Table};

fn main() {
    header("Fig 11 — SoC energy reduction ratios");
    print!("{}", ent::report::fig11());

    // Per-network detail (the bars behind the ranges).
    let mut t = Table::new("\nper-network detail").header(&[
        "network", "2D Matrix", "SA (OS)", "SA (WS)", "1D/2D", "Cube",
    ]);
    for net in zoo::paper_networks() {
        let mut row = vec![net.name.to_string()];
        for arch in [
            ALL_ARCHS[0], // matrix2d
            ALL_ARCHS[2], // sa_os
            ALL_ARCHS[3], // sa_ws
            ALL_ARCHS[1], // array1d2d
            ALL_ARCHS[4], // cube3d
        ] {
            row.push(pct(energy::reduction_ratio(arch, &net)));
        }
        t.row(row);
    }
    print!("{}", t.render());
}
