//! Bench target for **Fig 6** — the TCU area (a–c) and power (d–f) grid:
//! five architectures × three sizes × three variants, plus a timing of
//! the array cost roll-up itself.

use ent::arch::{Tcu, ALL_ARCHS, ALL_SCALES};
use ent::pe::Variant;
use ent::util::bench::{black_box, header, Suite};

fn main() {
    header("Fig 6 — TCU area/power grid");
    print!("{}", ent::report::fig6());

    header("cost-model roll-up microbenchmarks");
    let mut suite = Suite::new();
    suite.bench("tcu_cost_full_grid_45_instances", || {
        let mut acc = 0.0;
        for arch in ALL_ARCHS {
            for scale in ALL_SCALES {
                let s = arch.size_for_scale(scale);
                for v in ent::pe::Variant::ALL {
                    acc += Tcu::new(arch, s, v).cost().total().area_um2;
                }
            }
        }
        black_box(acc);
    });
    suite.bench_val("tcu_cost_single_64x64", || {
        Tcu::new(ent::arch::ArchKind::SystolicOs, 64, Variant::EntOurs)
            .cost()
            .total()
            .area_um2
    });
}
