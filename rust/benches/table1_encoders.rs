//! Bench target for **Table 1** — regenerates all three sub-tables
//! (single-encoder gates, high-bit encoder sweep, INT8 multiplier
//! comparison) and micro-benchmarks the functional encoder/multiplier
//! models that produce them.

use ent::arith::multiplier::{MultKind, Multiplier};
use ent::encoding::ent::{encode_signed, encode_unsigned};
use ent::encoding::mbe::booth_digits;
use ent::util::bench::{black_box, header, Suite};
use ent::util::prng::Rng;

fn main() {
    header("Table 1 — encoder & multiplier comparison");
    print!("{}", ent::report::table1());

    header("functional-model microbenchmarks");
    let mut suite = Suite::new();
    let mut rng = Rng::new(1);
    let vals: Vec<i64> = (0..4096).map(|_| rng.range_i64(-128, 127)).collect();
    let uvals: Vec<i64> = (0..4096).map(|_| rng.range_i64(0, 255)).collect();

    let mut i = 0;
    suite.bench("ent_encode_signed_int8", || {
        i = (i + 1) & 4095;
        black_box(encode_signed(vals[i], 8));
    });
    let mut j = 0;
    suite.bench("ent_encode_unsigned_16bit", || {
        j = (j + 1) & 4095;
        black_box(encode_unsigned(uvals[j] * 256 + 17, 16));
    });
    let mut k = 0;
    suite.bench("mbe_booth_digits_int8", || {
        k = (k + 1) & 4095;
        black_box(booth_digits(vals[k], 8));
    });

    for kind in [MultKind::MbeInternal, MultKind::EntInternal, MultKind::EntRme] {
        let m = Multiplier::new(kind, 8);
        let mut x = 0;
        suite.bench(&format!("mul_{}", kind.name().replace(' ', "_")), || {
            x = (x + 1) & 4095;
            black_box(m.mul(vals[x], vals[4095 - x]));
        });
    }

    // Cost-model evaluation itself (used in hot loops by fig6/fig7).
    suite.bench_val("encoder_cost_model_sweep", || {
        use ent::encoding::{ent::Ent, mbe::Mbe, Encoding};
        let mut acc = 0.0;
        for n in [8usize, 16, 24, 32] {
            acc += Mbe.encoder_cost(n).area_um2 + Ent.encoder_cost(n).area_um2;
        }
        acc
    });
}
