//! Bench target for **Fig 7** — area-/energy-efficiency up-ratios per
//! computational scale (256 GOPS / 1 TOPS / 4 TOPS), with the paper's
//! averages printed alongside for comparison.

use ent::arch::{Tcu, ALL_ARCHS, ALL_SCALES};
use ent::pe::Variant;
use ent::util::bench::{black_box, header, Suite};

fn main() {
    header("Fig 7 — efficiency up-ratios");
    print!("{}", ent::report::fig7());

    header("efficiency evaluation microbenchmark");
    let mut suite = Suite::new();
    suite.bench("fig7_full_sweep", || {
        let mut acc = 0.0;
        for arch in ALL_ARCHS {
            for scale in ALL_SCALES {
                let s = arch.size_for_scale(scale);
                let b = Tcu::new(arch, s, Variant::Baseline);
                let e = Tcu::new(arch, s, Variant::EntOurs);
                acc += e.area_efficiency() / b.area_efficiency();
                acc += e.energy_efficiency() / b.energy_efficiency();
            }
        }
        black_box(acc);
    });
}
