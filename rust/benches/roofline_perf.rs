//! Roofline sweep + tile-plan autotuner benchmark.
//!
//! Two halves, mirroring `ent report roofline`:
//!
//! * an **analytic sweep** over square GEMMs (128 → 8192) and the real
//!   serving shapes of `TransformerSpec::tiny()` (prefill Q/K/V and
//!   attention scores, MLP tiles, m=1 decode rows, the logits head) —
//!   closed-form planner event counts per architecture, so the 8192³
//!   point costs nothing to "run";
//! * a **measured default-vs-tuned** grid on the small shapes the
//!   schedulers actually execute: each (arch, variant, shape) GEMM runs
//!   once with the static `TilePlan::new` blocking + `par_bands` split
//!   and once with the `PlanTuner`'s calibrated choice. Tuned output is
//!   asserted bit-identical to the default before any timing — the
//!   tuner may only move time, never values.
//!
//! Emits `BENCH_roofline.json` at the workspace root — `ns_per_mac`
//! (default plan) and `ns_per_mac_tuned` per measured row, both gated
//! higher-worse by scripts/bench_compare.

use ent::arch::{default_bands, Tcu, TcuEngine, ALL_ARCHS};
use ent::nn::transformer::TransformerSpec;
use ent::pe::Variant;
use ent::sim::autotune::PlanTuner;
use ent::sim::{GemmShape, TilePlan};
use ent::util::bench::{black_box, header, Suite};
use ent::util::json::Json;
use ent::util::prng::Rng;

fn main() {
    header("roofline sweep + tile-plan autotuner");
    let mut suite = Suite::new();
    let mut rng = Rng::new(0x800F);
    let mut json_rows: Vec<Json> = Vec::new();

    // --- analytic roofline: square sizes 128 → 8192 per arch ---------
    let spec = TransformerSpec::tiny();
    let ctx = spec.max_seq;
    let head_dim = spec.d_model / spec.heads;
    println!("analytic sweep (planner event model, EN-T Ours):");
    for arch in ALL_ARCHS {
        let s = if arch == ent::arch::ArchKind::Cube3d { 8 } else { 16 };
        let tcu = Tcu::new(arch, s, Variant::EntOurs);
        for dim in [128usize, 256, 512, 1024, 2048, 4096, 8192] {
            let g = GemmShape::new(dim, dim, dim);
            let st = TilePlan::new(&tcu, g).stats();
            println!(
                "  {:<10} {dim:>5}^3  util {:.3}  cycles {}",
                arch.short_name(),
                st.utilization,
                st.cycles
            );
            json_rows.push(analytic_row(
                format!("roofline_sq{dim}_{}", arch.short_name()),
                arch.short_name(),
                g,
                st,
            ));
        }
        // Real serving shapes from the tiny transformer geometry.
        for (sname, g) in [
            ("prefill_qkv", GemmShape::new(ctx / 2, spec.d_model, spec.d_model)),
            ("prefill_score", GemmShape::new(ctx / 2, head_dim, ctx / 2)),
            ("mlp", GemmShape::new(ctx, spec.d_model, spec.d_ff)),
            ("decode_attn", GemmShape::new(1, head_dim, ctx)),
            ("decode_mlp", GemmShape::new(1, spec.d_model, spec.d_ff)),
            ("decode_head", GemmShape::new(1, spec.d_model, spec.vocab)),
        ] {
            let st = TilePlan::new(&tcu, g).stats();
            json_rows.push(analytic_row(
                format!("roofline_{sname}_{}", arch.short_name()),
                arch.short_name(),
                g,
                st,
            ));
        }
    }

    // --- measured: default blocking vs calibrated tuner choice -------
    // One shared tuner, exactly like a serving run with --autotune on:
    // each (arch, size, variant, shape-class) calibrates once, then the
    // timed loops replay the cached winner.
    let tuner = PlanTuner::new();
    let shapes = [
        ("sq128", GemmShape::new(128, 128, 128)),
        ("mlp", GemmShape::new(ctx, spec.d_model, spec.d_ff)),
        ("decode_mlp", GemmShape::new(1, spec.d_model, spec.d_ff)),
    ];
    for arch in ALL_ARCHS {
        for variant in Variant::ALL {
            let s = if arch == ent::arch::ArchKind::Cube3d { 8 } else { 16 };
            let eng = Tcu::new(arch, s, variant).engine();
            for (sname, g) in shapes {
                let a = rng.i8_vec(g.m * g.k);
                let b = rng.i8_vec(g.k * g.n);
                let mut c = vec![0i64; g.m * g.n];
                let def_plan = TilePlan::new(eng.tcu(), g);
                let def_bands = default_bands(eng.tcu(), g);
                let (plan, bands) = tuner.choose(&eng, g);
                // Bit-identity first: the tuned plan must compute the
                // same integers as the default before it earns timing.
                eng.matmul_into_planned(&a, &b, &mut c, &def_plan, def_bands);
                let want = c.clone();
                eng.matmul_into_planned(&a, &b, &mut c, &plan, bands);
                assert_eq!(c, want, "tuned plan changed values on {sname}");

                let name = format!("plan_{}_{}_{sname}", arch.short_name(), variant.name());
                let macs = g.macs() as f64;
                let def = suite
                    .bench(&format!("{name}_default"), || {
                        eng.matmul_into_planned(&a, &b, &mut c, &def_plan, def_bands);
                        black_box(&c);
                    })
                    .clone();
                let tuned = suite
                    .bench(&format!("{name}_tuned"), || {
                        eng.matmul_into_planned(&a, &b, &mut c, &plan, bands);
                        black_box(&c);
                    })
                    .clone();
                json_rows.push(Json::obj(vec![
                    ("name", Json::str(name)),
                    ("arch", Json::str(arch.short_name())),
                    ("variant", Json::str(variant.name())),
                    ("m", Json::num(g.m as f64)),
                    ("k", Json::num(g.k as f64)),
                    ("n", Json::num(g.n as f64)),
                    ("ns_per_mac", Json::num(def.ns_per_iter.mean / macs)),
                    ("ns_per_mac_tuned", Json::num(tuned.ns_per_iter.mean / macs)),
                    ("tuned_tm", Json::num(plan.tm as f64)),
                    ("tuned_tk", Json::num(plan.tk as f64)),
                    ("tuned_tn", Json::num(plan.tn as f64)),
                    ("tuned_bands", Json::num(bands as f64)),
                ]));
            }
        }
    }
    let ts = tuner.stats();
    println!(
        "plan tuner: {} calibrations, {} hits / {} misses ({} entries)",
        ts.tunes, ts.hits, ts.misses, ts.entries
    );

    // --- machine-readable trajectory file ----------------------------
    let out = Json::obj(vec![
        ("bench", Json::str("roofline_perf")),
        ("unit", Json::str("ns_per_mac / utilization")),
        ("results", Json::arr(json_rows)),
    ]);
    // Cargo runs benches with cwd = the package dir (rust/); anchor the
    // output at the workspace root so CI finds it deterministically.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_roofline.json");
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
}

/// One closed-form sweep row: planner event counts, no wall clock.
fn analytic_row(name: String, arch: &'static str, g: GemmShape, st: ent::sim::GemmStats) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("arch", Json::str(arch)),
        ("variant", Json::str(Variant::EntOurs.name())),
        ("m", Json::num(g.m as f64)),
        ("k", Json::num(g.k as f64)),
        ("n", Json::num(g.n as f64)),
        ("macs", Json::num(st.macs as f64)),
        ("cycles", Json::num(st.cycles as f64)),
        ("utilization", Json::num(st.utilization)),
        ("encodes", Json::num(st.encodes as f64)),
    ])
}
