//! Hot-path performance benchmark (deliverable (e) — EXPERIMENTS.md
//! §Perf). Covers every layer the request path touches:
//!
//! * L3 functional models: encoded MAC, bit-level datapath, tiled GEMM;
//! * L3 analytics: dataflow stats + SoC frame simulation (the "digital
//!   twin" that runs per request);
//! * runtime: PJRT artifact execution (gated on `make artifacts`);
//! * coordinator: end-to-end request round-trip incl. dynamic batching.

use ent::arch::{ArchKind, Tcu};
use ent::coordinator::{Config, Coordinator, InferRequest};
use ent::encoding::ent::encode_signed;
use ent::nn::zoo;
use ent::pe::Variant;
use ent::runtime::{default_artifact_dir, Runtime};
use ent::sim::{gemm_stats, tiled_matmul, GemmShape};
use ent::soc::{energy, Soc};
use ent::util::bench::{black_box, header, Suite};
use ent::util::prng::Rng;

fn main() {
    header("hot-path performance");
    let mut suite = Suite::new();
    let mut rng = Rng::new(0xF00D);

    // --- L3 functional datapath ---
    let codes: Vec<_> = (0..256).map(|i| encode_signed(i - 128, 8)).collect();
    let m = ent::arith::multiplier::Multiplier::new(
        ent::arith::multiplier::MultKind::EntRme,
        8,
    );
    let mut i = 0usize;
    suite.bench("mac_encoded_bitlevel", || {
        i = (i + 1) & 255;
        black_box(m.mul_encoded(&codes[i], (i as i64) - 128));
    });

    let tcu = Tcu::new(ArchKind::SystolicOs, 16, Variant::EntOurs);
    let a = rng.i8_vec(32 * 48);
    let b = rng.i8_vec(48 * 32);
    suite.bench("tiled_matmul_32x48x32_bitlevel", || {
        black_box(tiled_matmul(&tcu, &a, &b, 32, 48, 32));
    });

    // --- L3 analytics (per-request digital twin work) ---
    let tcu32 = Tcu::new(ArchKind::SystolicOs, 32, Variant::EntOurs);
    suite.bench("gemm_stats_resnet_layer", || {
        black_box(gemm_stats(&tcu32, GemmShape::new(256, 2304, 196)));
    });
    let soc = Soc::paper_config(ArchKind::SystolicOs, Variant::EntOurs);
    let resnet50 = zoo::by_name("resnet50").unwrap();
    let r = suite.bench("frame_energy_resnet50", || {
        black_box(energy::frame_energy(&soc, &resnet50).0.total_pj());
    });
    println!(
        "  -> digital-twin rate: {:.0} resnet50-frames/s ({:.1} G MACs modelled/s)",
        r.throughput(),
        resnet50.total_macs() as f64 * r.throughput() / 1e9
    );

    // --- runtime + coordinator (artifact-gated) ---
    if default_artifact_dir().join("gemm_64x128x64.hlo.txt").exists() {
        let mut rt = Runtime::cpu().expect("pjrt");
        rt.load_file(
            "gemm_64x128x64",
            &default_artifact_dir().join("gemm_64x128x64.hlo.txt"),
        )
        .expect("load");
        let ga = rng.i8_vec(64 * 128);
        let gb = rng.i8_vec(128 * 64);
        suite.bench("pjrt_gemm_64x128x64", || {
            black_box(rt.gemm_i8("gemm_64x128x64", &ga, &gb, 64, 128, 64).unwrap());
        });

        // Direct model execution (no coordinator) — the denominator for
        // the coordinator-overhead target (< 10 %, DESIGN.md §7).
        rt.load_file(
            "tinynet_b1",
            &default_artifact_dir().join("tinynet_b1.hlo.txt"),
        )
        .expect("load tinynet");
        let img_direct = rng.i8_vec(3 * 32 * 32);
        let direct = suite.bench("pjrt_tinynet_b1_direct", || {
            black_box(
                rt.cnn_forward("tinynet_b1", &img_direct, 1, (3, 32, 32))
                    .unwrap(),
            );
        });
        let direct_ns = direct.ns_per_iter.mean;

        let coord = Coordinator::start(Config::default()).expect("coordinator");
        let img = rng.i8_vec(3 * 32 * 32);
        let rr = suite.bench("coordinator_round_trip_b1", || {
            black_box(
                coord
                    .infer(InferRequest { image: img.clone() })
                    .expect("infer"),
            );
        });
        println!(
            "  -> serving throughput (unbatched lower bound): {:.0} req/s",
            rr.throughput()
        );
        println!(
            "  -> coordinator overhead vs direct execute: {:+.1}% (target < 10%)",
            (rr.ns_per_iter.mean / direct_ns - 1.0) * 100.0
        );
        let snap = coord.metrics();
        if let Some(lat) = snap.latency_us {
            println!(
                "  -> request latency µs: mean {:.0} p95 {:.0}",
                lat.mean, lat.p95
            );
        }
        coord.shutdown();
    } else {
        println!("(artifacts not built — runtime/coordinator benches skipped; run `make artifacts`)");
    }
}
