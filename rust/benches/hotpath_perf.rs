//! Hot-path performance benchmark — covers every layer the request
//! path touches:
//!
//! * L3 functional models: encoded MAC (packed LUT), bit-level datapath,
//!   tiled GEMM through every `TcuEngine` (arch × variant grid at the
//!   32×32 scale), and the parallel row-band path on a larger GEMM;
//! * L3 analytics: planner stats + SoC frame simulation (the "digital
//!   twin" that runs per request);
//! * serving: coordinator round-trip on the native engine-shard backend
//!   (plus the artifact path when `make artifacts` has run).
//!
//! Emits `BENCH_hotpath.json` next to the CWD — machine-readable GEMM/s
//! and ns/MAC per arch × variant — so the perf trajectory is tracked
//! across PRs.

use ent::arch::{ArchKind, MatOperand, Scale, Tcu, TcuEngine, ALL_ARCHS};
use ent::coordinator::{Config, Coordinator, InferRequest};
use ent::encoding::packed::lut_i8;
use ent::encoding::prepacked::{CachedWeight, EncodeCache};
use ent::nn::zoo;
use ent::pe::Variant;
use ent::runtime::{default_artifact_dir, Runtime};
use ent::sim::{gemm_stats, tiled_matmul, GemmShape};
use ent::soc::{energy, Soc};
use ent::util::bench::{black_box, header, BenchResult, Suite};
use ent::util::json::Json;
use ent::util::prng::Rng;

fn main() {
    header("hot-path performance");
    let mut suite = Suite::new();
    let mut rng = Rng::new(0xF00D);
    let mut json_rows: Vec<Json> = Vec::new();

    // --- L3 functional datapath ---
    let codes: Vec<_> = (0..256).map(|i| lut_i8((i - 128) as i8)).collect();
    let m = ent::arith::multiplier::Multiplier::new(
        ent::arith::multiplier::MultKind::EntRme,
        8,
    );
    let mut i = 0usize;
    suite.bench("mac_encoded_bitlevel", || {
        i = (i + 1) & 255;
        black_box(m.mul_packed(codes[i], (i as i64) - 128));
    });

    let tcu = Tcu::new(ArchKind::SystolicOs, 16, Variant::EntOurs);
    let a = rng.i8_vec(32 * 48);
    let b = rng.i8_vec(48 * 32);
    suite.bench("tiled_matmul_32x48x32_bitlevel", || {
        black_box(tiled_matmul(&tcu, &a, &b, 32, 48, 32));
    });

    // --- arch × variant GEMM grid at the 32×32 (256 GOPS) scale ---
    // 32³ GEMM per iteration → GEMM/s and ns/MAC per engine, both with
    // the stationary operand encoded on the fly (`ns_per_mac`) and
    // through the warm encode-cache path (`ns_per_mac_cached`: a
    // `CachedWeight::resolve` per GEMM — the mutex + probe the serving
    // helpers really pay — then the prepacked entry; the A operand is
    // the weight side by the repo's GEMM convention). Non-EN-T variants
    // mirror the serving helpers' gate and skip the resolve, so cached
    // ≈ uncached there by construction.
    let (gm, gk, gn) = (32usize, 32usize, 32usize);
    let ga = rng.i8_vec(gm * gk);
    let gb = rng.i8_vec(gk * gn);
    let wa = CachedWeight::new(ga.clone(), gm, gk);
    let cache = EncodeCache::new(64 << 20);
    let macs = (gm * gk * gn) as f64;
    for arch in ALL_ARCHS {
        for variant in Variant::ALL {
            let size = arch.size_for_scale(Scale::Gops256);
            let eng = Tcu::new(arch, size, variant).engine();
            let name = format!("gemm32_{}_{}", arch.short_name(), variant.name());
            let plain = suite
                .bench(&name, || {
                    black_box(eng.matmul(&ga, &gb, gm, gk, gn));
                })
                .clone();
            let mut c = vec![0i64; gm * gn];
            let cached = suite
                .bench(&format!("{name}_cached"), || {
                    if variant.consumes_codes() {
                        let pm = wa.resolve(&cache);
                        eng.matmul_prepacked_into(
                            MatOperand::Packed(&pm),
                            MatOperand::Raw(&gb),
                            &mut c,
                            gm,
                            gk,
                            gn,
                        );
                    } else {
                        eng.matmul_into(&ga, &gb, &mut c, gm, gk, gn);
                    }
                    black_box(&c);
                })
                .clone();
            json_rows.push(grid_row(arch, variant, gm, gk, gn, macs, &plain, Some(&cached)));
        }
    }

    // --- parallel row-band path on a larger bit-level GEMM ---
    let (pm, pk, pn) = (96usize, 64usize, 48usize);
    let pa = rng.i8_vec(pm * pk);
    let pb = rng.i8_vec(pk * pn);
    let peng = Tcu::new(ArchKind::SystolicOs, 16, Variant::EntOurs).engine();
    let r = suite
        .bench("gemm96x64x48_parallel_bands", || {
            black_box(peng.matmul(&pa, &pb, pm, pk, pn));
        })
        .clone();
    json_rows.push(grid_row(
        ArchKind::SystolicOs,
        Variant::EntOurs,
        pm,
        pk,
        pn,
        (pm * pk * pn) as f64,
        &r,
        None,
    ));

    // --- L3 analytics (per-request digital twin work) ---
    let tcu32 = Tcu::new(ArchKind::SystolicOs, 32, Variant::EntOurs);
    suite.bench("gemm_stats_resnet_layer", || {
        black_box(gemm_stats(&tcu32, GemmShape::new(256, 2304, 196)));
    });
    let soc = Soc::paper_config(ArchKind::SystolicOs, Variant::EntOurs);
    let resnet50 = zoo::by_name("resnet50").unwrap();
    let r = suite.bench("frame_energy_resnet50", || {
        black_box(energy::frame_energy(&soc, &resnet50).0.total_pj());
    });
    println!(
        "  -> digital-twin rate: {:.0} resnet50-frames/s ({:.1} G MACs modelled/s)",
        r.throughput(),
        resnet50.total_macs() as f64 * r.throughput() / 1e9
    );

    // --- serving: native engine-shard backend (always available) ---
    {
        // Direct model execution on one engine — the denominator for
        // the coordinator-overhead target (< 10 %, DESIGN.md §7).
        let model = ent::nn::forward::QuantCnn::tiny_native();
        let eng = Tcu::new(ArchKind::SystolicOs, 16, Variant::EntOurs).engine();
        let img = rng.i8_vec(3 * 32 * 32);
        let direct = suite.bench("native_forward_direct", || {
            black_box(model.forward(&eng, &img));
        });
        let direct_ns = direct.ns_per_iter.mean;

        let cfg = Config::builder().native(4).build().expect("config");
        let coord = Coordinator::start(cfg).expect("native coordinator");
        let rr = suite.bench("coordinator_native_round_trip", || {
            black_box(
                coord
                    .infer(InferRequest { image: img.clone() })
                    .expect("native infer"),
            );
        });
        println!(
            "  -> native serving throughput (unbatched lower bound): {:.0} req/s",
            rr.throughput()
        );
        println!(
            "  -> coordinator overhead vs direct execute: {:+.1}% (target < 10%)",
            (rr.ns_per_iter.mean / direct_ns - 1.0) * 100.0
        );
        coord.shutdown();
    }

    // --- runtime + coordinator (artifact-gated) ---
    if default_artifact_dir().join("gemm_64x128x64.hlo.txt").exists() {
        let mut rt = Runtime::cpu().expect("runtime");
        rt.load_file(
            "gemm_64x128x64",
            &default_artifact_dir().join("gemm_64x128x64.hlo.txt"),
        )
        .expect("load");
        let ga = rng.i8_vec(64 * 128);
        let gb = rng.i8_vec(128 * 64);
        suite.bench("runtime_gemm_64x128x64", || {
            black_box(rt.gemm_i8("gemm_64x128x64", &ga, &gb, 64, 128, 64).unwrap());
        });

        let coord = Coordinator::start(Config::default()).expect("coordinator");
        let img = rng.i8_vec(3 * 32 * 32);
        let rr = suite.bench("coordinator_round_trip_b1", || {
            black_box(
                coord
                    .infer(InferRequest { image: img.clone() })
                    .expect("infer"),
            );
        });
        println!(
            "  -> artifact serving throughput (unbatched lower bound): {:.0} req/s",
            rr.throughput()
        );
        let snap = coord.metrics();
        if let Some(lat) = snap.latency_us {
            println!(
                "  -> request latency µs: mean {:.0} p95 {:.0}",
                lat.mean, lat.p95
            );
        }
        coord.shutdown();
    } else {
        println!("(artifacts not built — artifact-path benches skipped; run `make artifacts`)");
    }

    // --- machine-readable trajectory file ---
    let out = Json::obj(vec![
        ("bench", Json::str("hotpath_perf")),
        ("unit", Json::str("ns_per_iter / gemms_per_s / ns_per_mac")),
        ("results", Json::arr(json_rows)),
    ]);
    // Cargo runs benches with cwd = the package dir (rust/); anchor the
    // output at the workspace root so CI finds it deterministically.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
}

#[allow(clippy::too_many_arguments)]
fn grid_row(
    arch: ArchKind,
    variant: Variant,
    m: usize,
    k: usize,
    n: usize,
    macs: f64,
    r: &BenchResult,
    cached: Option<&BenchResult>,
) -> Json {
    let mut fields = vec![
        ("name", Json::str(r.name.clone())),
        ("arch", Json::str(arch.short_name())),
        ("variant", Json::str(variant.name())),
        ("m", Json::num(m as f64)),
        ("k", Json::num(k as f64)),
        ("n", Json::num(n as f64)),
        ("ns_per_iter", Json::num(r.ns_per_iter.mean)),
        ("gemms_per_s", Json::num(r.throughput())),
        ("ns_per_mac", Json::num(r.ns_per_iter.mean / macs)),
    ];
    // Cached-vs-uncached contrast: the same GEMM with the stationary
    // operand pre-encoded (weight cache resident). Gated by
    // scripts/bench_compare like ns_per_mac.
    if let Some(c) = cached {
        fields.push(("ns_per_iter_cached", Json::num(c.ns_per_iter.mean)));
        fields.push(("ns_per_mac_cached", Json::num(c.ns_per_iter.mean / macs)));
    }
    Json::obj(fields)
}
