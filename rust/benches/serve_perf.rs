//! Serving-scheduler benchmark — the continuous-batching step loop vs
//! the window batcher under open-loop synthetic load
//! (`coordinator::loadgen`), at two arrival rates plus one mixed
//! CNN/token row.
//!
//! Emits `BENCH_serve.json` at the workspace root — tokens/s, p50/p99
//! end-to-end latency, rejection counts, and engine-shard occupancy per
//! scheduler × rate — so the serving trajectory is tracked across PRs
//! alongside `BENCH_hotpath.json` and `BENCH_transformer.json`, and the
//! CI bench-regression gate (`scripts/bench_compare`) can hold the
//! line on it. Quick mode (`ENT_BENCH_QUICK=1`) shortens the
//! submission window for CI smoke runs.

use ent::arch::ALL_ARCHS;
use ent::coordinator::loadgen::{self, LoadGen};
use ent::coordinator::{Config, Coordinator, DraftKind, Spec};
use ent::pe::Variant;
use ent::util::bench::header;
use ent::util::json::Json;

const SHARDS: usize = 4;

fn main() {
    header("serving scheduler performance");
    let quick = std::env::var("ENT_BENCH_QUICK").is_ok();
    let duration_ms: u64 = if quick { 200 } else { 1500 };
    let mut rows: Vec<Json> = Vec::new();

    // scheduler × rate grid on pure token traffic, then one mixed row,
    // the kv-prepack off contrast (continuous serves with the
    // append-only prepacked KV cache on by default — the _nopp row
    // shows the decode tokens/s delta at kv-prepack on vs off), the
    // Zipf prefix-popularity pair: `continuous_zipf` exercises the
    // shared prefix KV pool under realistic template traffic, and
    // `continuous_zipf_noshare` is the same workload with prefix
    // sharing off — the tokens/s and prefix_hit_rate gap is the
    // cross-request encode-reuse win — and the speculative-decoding
    // pair: `continuous_spec` drafts with the deterministic oracle
    // (acceptance_rate exactly 1.0, machine-independent, so the gate
    // can hold the line on it) and `continuous_spec_off` is the same
    // load without speculation, quoting the coalesced-verify tokens/s
    // contrast. The `_mt` pair is the multi-tenant SLO scorecard:
    // three Zipf tenants with bursty arrivals against a 250 ms
    // deadline, once on a 2+2 disaggregated pool split (`pools_mt`)
    // and once on the unified 4-shard scheduler (`continuous_mt`) —
    // p99 TTFT and goodput under deadline are the gated fields.
    let cases: [(&str, f64, f64, f64); 12] = [
        ("continuous", 100.0, 0.0, 0.0),
        ("continuous_nopp", 100.0, 0.0, 0.0),
        ("continuous", 300.0, 0.0, 0.0),
        ("window", 100.0, 0.0, 0.0),
        ("window", 300.0, 0.0, 0.0),
        ("continuous", 200.0, 0.25, 0.0),
        ("continuous_zipf", 400.0, 0.0, 1.1),
        ("continuous_zipf_noshare", 400.0, 0.0, 1.1),
        ("continuous_spec", 400.0, 0.0, 0.0),
        ("continuous_spec_off", 400.0, 0.0, 0.0),
        ("pools_mt", 400.0, 0.0, 1.1),
        ("continuous_mt", 400.0, 0.0, 1.1),
    ];
    for (scheduler, rate, mix, zipf) in cases {
        let cfg = match scheduler {
            "continuous" | "continuous_zipf" | "continuous_spec_off" | "continuous_mt" => {
                Config::builder().continuous(SHARDS).build()
            }
            "continuous_nopp" => Config::builder().continuous(SHARDS).kv_prepack(false).build(),
            "continuous_zipf_noshare" => {
                Config::builder().continuous(SHARDS).prefix_share(false).build()
            }
            "continuous_spec" => Config::builder()
                .continuous(SHARDS)
                .speculation(Spec::On { k: 4, draft: DraftKind::Oracle })
                .build(),
            "pools_mt" => Config::builder().pools(SHARDS / 2, SHARDS / 2).build(),
            _ => Config::builder().native(SHARDS).build(),
        }
        .expect("serving config");
        let coord = Coordinator::start(cfg).expect("coordinator");
        let mt = scheduler.ends_with("_mt");
        let load = LoadGen {
            rate_per_s: rate,
            duration_ms,
            prompt_len: 12,
            max_new_tokens: 4,
            image_mix: mix,
            prefix_zipf: zipf,
            seed: 0xBE7C,
            tenants: if mt { 3 } else { 1 },
            burst: if mt { 3.0 } else { 1.0 },
            slo_ms: if mt { 250.0 } else { 0.0 },
        };
        let r = loadgen::run(&coord, &load);
        let m = coord.metrics();
        coord.shutdown();
        let lat = r.latency_us.as_ref();
        let name = format!(
            "serve_{scheduler}_r{rate:.0}{}",
            if mix > 0.0 { "_mixed" } else { "" }
        );
        println!(
            "{name:<34} sent {:>4}  done {:>4}  rejected {:>3}  p50 {:>9.0} µs  p99 {:>9.0} µs  \
             {:>7.0} tokens/s  occupancy {:>4.0}%",
            r.sent,
            r.completed,
            r.rejected,
            lat.map(|l| l.median).unwrap_or(f64::NAN),
            lat.map(|l| l.p99).unwrap_or(f64::NAN),
            r.tokens_per_s,
            r.occupancy * 100.0
        );
        // The LoadReport fields (incl. null-for-missing latency) come
        // from the shared emitter so this file and `ent loadgen --json`
        // cannot drift.
        let mut fields = vec![
            ("name", Json::str(name)),
            ("scheduler", Json::str(scheduler)),
            ("rate_per_s", Json::num(rate)),
            ("image_mix", Json::num(mix)),
        ];
        fields.extend(r.json_fields());
        // Cache-residency context (ungated): how much of the attention
        // history entered the GEMMs pre-encoded.
        fields.push(("kv_rows_encoded", Json::num(m.kv_rows_encoded as f64)));
        fields.push(("kv_rows_reused", Json::num(m.kv_rows_reused as f64)));
        // Disaggregation context (ungated): prefill→decode handoffs
        // completed — 0 everywhere except the pooled rows.
        fields.push(("handoffs", Json::num(m.handoffs as f64)));
        rows.push(Json::obj(fields));
    }

    // --- arch × variant serving grid -------------------------------
    // One short continuous-batching run per (architecture, variant)
    // twin, so the serving trajectory covers the full grid the
    // functional tests lock (`tests/serve_equivalence.rs`) and
    // scripts/grid_check can assert arch × Variant::ALL coverage on
    // this file like the other trajectory benches. Lower rate and a
    // shorter window than the scheduler rows — these gate relative
    // tokens/s per grid point, not scheduler behaviour.
    let grid_duration_ms: u64 = if quick { 100 } else { 400 };
    for arch in ALL_ARCHS {
        for variant in Variant::ALL {
            let cfg = Config::builder()
                .continuous(2)
                .twin(arch, variant)
                .build()
                .expect("grid config");
            let coord = Coordinator::start(cfg).expect("grid coordinator");
            let load = LoadGen {
                rate_per_s: 100.0,
                duration_ms: grid_duration_ms,
                prompt_len: 8,
                max_new_tokens: 3,
                seed: 0xBE7C,
                ..Default::default()
            };
            let r = loadgen::run(&coord, &load);
            coord.shutdown();
            let name = format!("serve_grid_{}_{}", arch.short_name(), variant.name());
            println!(
                "{name:<34} sent {:>4}  done {:>4}  {:>7.0} tokens/s",
                r.sent, r.completed, r.tokens_per_s
            );
            let mut fields = vec![
                ("name", Json::str(name)),
                ("scheduler", Json::str("continuous")),
                ("arch", Json::str(arch.short_name())),
                ("variant", Json::str(variant.name())),
                ("rate_per_s", Json::num(100.0)),
            ];
            fields.extend(r.json_fields());
            rows.push(Json::obj(fields));
        }
    }

    let out = Json::obj(vec![
        ("bench", Json::str("serve_perf")),
        ("unit", Json::str("tokens_per_s / latency_us / occupancy")),
        ("shards", Json::num(SHARDS as f64)),
        ("duration_ms", Json::num(duration_ms as f64)),
        ("results", Json::arr(rows)),
    ]);
    // Cargo runs benches with cwd = the package dir (rust/); anchor the
    // output at the workspace root so CI finds it deterministically.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
}
