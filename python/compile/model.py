"""Layer-2: the quantized CNN ("TinyNet") whose convolutions run through
the EN-T encoded-matmul Pallas kernel.

Architecture (must stay in sync with rust `nn::zoo::tinynet`):

    conv1 3→16  3×3 s1 p1   (32×32)
    conv2 16→32 3×3 s2 p1   (→16×16)
    conv3 32→64 3×3 s2 p1   (→8×8)
    global average pool → fc 64→10 → f32 logits

Everything on the conv path is INT8 with INT32 accumulation and a
right-shift requantization — the arithmetic the paper's TCUs execute.
Weights are deterministic pseudo-random int8 baked into the graph at
lowering time (seeded; the rust side never sees Python).
"""

import jax
import jax.numpy as jnp

from .kernels import ent


def pad2(x, mult0, mult1):
    """Zero-pad a 2-D array so each dim is a multiple of the given
    tile multiples (zeros do not change the GEMM result)."""
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 == 0 and p1 == 0:
        return x
    return jnp.pad(x, ((0, p0), (0, p1)))


def im2col(x, kernel, stride, pad):
    """NCHW int8 → (C·k², N·H'·W') patch matrix (the SoC's img2col)."""
    n, c, h, w = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x.astype(jnp.float32),
        filter_shape=(kernel, kernel),
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
    )  # (N, C*k*k, H', W')
    _, ck2, ho, wo = patches.shape
    cols = patches.astype(jnp.int8).transpose(1, 0, 2, 3).reshape(ck2, n * ho * wo)
    return cols, (ho, wo)


def conv_ent(x, w, stride, pad, shift=7):
    """INT8 convolution through the EN-T kernel.

    ``x``: (N, Cin, H, W) int8; ``w``: (Cout, Cin, k, k) int8.
    Returns (N, Cout, H', W') int8 after ReLU + right-shift requant.
    """
    cout, cin, k, _ = w.shape
    n = x.shape[0]
    cols, (ho, wo) = im2col(x, k, stride, pad)  # (cin*k², N·ho·wo)
    wmat = w.reshape(cout, cin * k * k)

    # Pad to kernel-tile multiples; the EN-T reuse lives inside the tile.
    bm, bn = 8, 128
    wmat_p = pad2(wmat, bm, 1)
    cols_p = pad2(cols, 1, bn)
    acc = ent.ent_matmul(wmat_p, cols_p, bm=bm, bn=bn)
    acc = acc[:cout, : n * ho * wo]

    out = jnp.maximum(acc, 0) >> shift  # ReLU + requantize
    out = jnp.clip(out, -128, 127).astype(jnp.int8)
    return out.reshape(cout, n, ho, wo).transpose(1, 0, 2, 3)


def make_weights(seed=0x0EA7):
    """Deterministic int8 weights (clipped unit-normal × 32)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)

    def w(k, shape):
        v = jax.random.normal(k, shape) * 32.0
        return jnp.clip(jnp.round(v), -127, 127).astype(jnp.int8)

    return {
        "conv1": w(ks[0], (16, 3, 3, 3)),
        "conv2": w(ks[1], (32, 16, 3, 3)),
        "conv3": w(ks[2], (64, 32, 3, 3)),
        "fc": w(ks[3], (10, 64)),
    }


def tinynet_forward(x, weights=None):
    """Forward pass: (N, 3, 32, 32) int8 → (N, 10) f32 logits."""
    w = weights if weights is not None else make_weights()
    h = conv_ent(x, w["conv1"], stride=1, pad=1)
    h = conv_ent(h, w["conv2"], stride=2, pad=1)
    h = conv_ent(h, w["conv3"], stride=2, pad=1)
    # Global average pool in int32, then the classifier head in f32.
    pooled = h.astype(jnp.int32).mean(axis=(2, 3))  # (N, 64)
    logits = pooled.astype(jnp.float32) @ w["fc"].T.astype(jnp.float32)
    return logits / 128.0


def gemm_ent(a, b):
    """Plain EN-T GEMM entry point for the serving tiles (aot exports a
    family of fixed shapes)."""
    return ent.ent_matmul(a, b)
