"""Layer-1: EN-T Pallas kernels.

The paper's compute hot-spot — the encoded multiply-accumulate datapath —
rethought for the TPU memory hierarchy (DESIGN.md §Hardware-Adaptation):

* the ASIC hoists the radix-4 carry-chain encoder out of every PE and
  reuses the encoded multiplicand across an array row;
* the TPU analogue encodes the stationary operand (the weights) ONCE per
  (bm × bk) tile held in VMEM, then reuses the digit planes for every
  column tile of B — the same reuse ratio per encode the ASIC row gets.

The encoded product is computed digit-plane by digit-plane:

    A·B = Σ_{i<4} 4^i · (s ⊙ wᵢ) @ B        (wᵢ ∈ {-1,0,1,2}, s = sign A)

so the kernel is pure int32 shift-add over four small matmuls — exactly
the paper's Eq. 5 with the Cin term vanishing for |A| ≤ 128 (int8
magnitudes; proven exhaustively in the rust model and asserted here).

All kernels run with ``interpret=True``: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Number of radix-4 digits for int8 operands.
N_DIGITS = 4


def encode_digit_planes(a):
    """Carry-chain encode int8 ``a`` (paper Eq. 7/8/16/17).

    Returns ``(sign, [w0..w3], cin)`` where ``sign`` is ±1 (int32), each
    ``wᵢ`` is an int32 plane with values in {-1, 0, 1, 2}, and ``cin`` is
    the final carry (always 0 for int8 magnitudes — kept for the
    width-generic tests).
    """
    a = a.astype(jnp.int32)
    sign = jnp.where(a < 0, -1, 1)
    mag = jnp.abs(a)
    planes = []
    carry = jnp.zeros_like(mag)
    for i in range(N_DIGITS):
        a_i = (mag >> (2 * i)) & 3
        a_prime = a_i + carry
        w = jnp.where(a_prime <= 2, a_prime, a_prime - 4)
        carry = (a_prime >= 3).astype(jnp.int32)
        planes.append(w)
    return sign, planes, carry


def encode_wire_bits(a):
    """The transmitted n+1-bit pattern (sign<<8 | 2-bit digit fields) —
    the cross-layer contract checked against the rust ``EntCode``."""
    sign, planes, _cin = encode_digit_planes(a)
    bits = jnp.zeros(a.shape, jnp.int32)
    for i, w in enumerate(planes):
        bits = bits | ((w & 3) << (2 * i))
    return jnp.where(sign < 0, bits | (1 << 8), bits)


def _ent_matmul_kernel(a_ref, b_ref, o_ref):
    """One (bm × bn) output tile: encode the A tile once, reuse the digit
    planes across the whole B tile (the EN-T reuse, in VMEM)."""
    a = a_ref[...]
    b = b_ref[...].astype(jnp.int32)
    sign, planes, _cin = encode_digit_planes(a)
    acc = jnp.zeros(o_ref.shape, jnp.int32)
    for i, w in enumerate(planes):
        signed_digit = sign * w  # ∈ {-2,-1,0,1,2}
        acc = acc + (jnp.dot(signed_digit, b) << (2 * i))
    o_ref[...] = acc


def ent_matmul(a, b, *, bm=None, bn=None):
    """C[m,n] = A[m,k] · B[k,n] through the EN-T encoded datapath.

    ``a``/``b`` are int8; the result is int32. Tiles: (bm × k) of A and
    (k × bn) of B per grid step; bm/bn default to the full problem
    (callers pad to multiples — see ``model.pad2``).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"shape mismatch {a.shape} @ {b.shape}"
    assert a.dtype == jnp.int8 and b.dtype == jnp.int8
    bm = bm or m
    bn = bn or n
    assert m % bm == 0 and n % bn == 0, f"tile {bm}x{bn} must divide {m}x{n}"
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _ent_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(a, b)


def _encode_kernel(a_ref, o_ref):
    o_ref[...] = encode_wire_bits(a_ref[...])


def ent_encode(a):
    """Standalone encoder kernel: int8 → packed wire bits (int32)."""
    return pl.pallas_call(
        _encode_kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.int32),
        interpret=True,
    )(a)


@functools.lru_cache(maxsize=None)
def tile_footprint_bytes(bm, bk, bn):
    """Estimated VMEM bytes for one grid step (DESIGN.md §9): the int8 A
    tile, its four int32 digit planes + sign, the int8 B tile, and the
    int32 accumulator."""
    a = bm * bk
    planes = (N_DIGITS + 1) * bm * bk * 4
    b = bk * bn
    acc = bm * bn * 4
    return a + planes + b + acc
