"""Pure-jnp/python correctness oracles for the EN-T kernels.

``matmul_ref`` is the ground truth the Pallas kernel must match exactly
(integer arithmetic — no tolerance). ``encode_ref`` is a direct, scalar
transcription of the paper's Eq. 7/8/16/17, independent of the kernel's
vectorized implementation, used to cross-check digit planes and the wire
format shared with the rust model.
"""

import jax.numpy as jnp


def matmul_ref(a, b):
    """Exact int32 GEMM oracle."""
    return a.astype(jnp.int32) @ b.astype(jnp.int32)


def encode_ref(value, width=8):
    """Scalar EN-T encode of ``abs(value)`` per the paper's recursion.

    Returns ``(sign, digits, cin)`` with digits LSB-first in
    {-1, 0, 1, 2}; mirrors rust `encoding::ent::encode_signed`.
    """
    assert -(1 << (width - 1)) <= value <= (1 << (width - 1)) - 1
    sign = value < 0
    mag = abs(value)
    digits = []
    carry = 0
    for i in range(width // 2):
        a_i = (mag >> (2 * i)) & 3
        a_prime = a_i + carry
        if a_prime <= 2:
            digits.append(a_prime)
            carry = 0
        else:
            digits.append(a_prime - 4)
            carry = 1
    return sign, digits, carry


def decode_ref(sign, digits, cin):
    """Inverse of ``encode_ref`` — Σ wᵢ·4ⁱ + cin·4^N, sign applied."""
    mag = sum(w * (4**i) for i, w in enumerate(digits))
    mag += cin * (4 ** len(digits))
    return -mag if sign else mag


def wire_bits_ref(value, width=8):
    """Packed wire pattern: sign<<width | 2-bit digit fields."""
    sign, digits, _cin = encode_ref(value, width)
    bits = 0
    for i, w in enumerate(digits):
        bits |= (w & 3) << (2 * i)
    if sign:
        bits |= 1 << width
    return bits
