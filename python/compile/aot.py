"""AOT compile path: lower the L2/L1 graphs to HLO **text** artifacts.

HLO text — NOT ``lowered.compile()`` or serialized protos — is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which the rust side's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (written to --out, default ../artifacts):

    tinynet_b{1,2,4,8}.hlo.txt   quantized CNN forward, per batch size
    gemm_{M}x{K}x{N}.hlo.txt     EN-T encoded GEMM tiles for serving
    encode8.hlo.txt              standalone encoder (wire-bit contract)
    tinyformer.hlo.txt           registry marker: the int8 transformer is
                                 executed natively by rust nn::transformer

Usage:  python -m compile.aot [--out DIR] [--report]
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ent

# GEMM tile family exported for the serving/runtime path.
GEMM_SHAPES = [(32, 32, 32), (64, 128, 64), (128, 256, 128)]
BATCH_SIZES = [1, 2, 4, 8]


def to_hlo_text(lowered):
    """StableHLO → XlaComputation → HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_tinynet(batch):
    spec = jax.ShapeDtypeStruct((batch, 3, 32, 32), jnp.int8)
    weights = model.make_weights()

    def fwd(x):
        return (model.tinynet_forward(x, weights),)

    return jax.jit(fwd).lower(spec)


def lower_gemm(m, k, n):
    sa = jax.ShapeDtypeStruct((m, k), jnp.int8)
    sb = jax.ShapeDtypeStruct((k, n), jnp.int8)

    def g(a, b):
        return (model.gemm_ent(a, b),)

    return jax.jit(g).lower(sa, sb)


def lower_encoder(length=256):
    spec = jax.ShapeDtypeStruct((length,), jnp.int8)

    def e(a):
        return (ent.ent_encode(a),)

    return jax.jit(e).lower(spec)


def write(path, text):
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


def structural_report():
    """L1 perf deliverable: VMEM footprint + reuse ratio per tile shape
    (interpret mode has no meaningful wallclock; structure is the
    optimization target — DESIGN.md §7/§9)."""
    print("\n== L1 structural report (EN-T kernel tiles) ==")
    print(f"{'bm':>4} {'bk':>5} {'bn':>5} {'VMEM KiB':>9} {'reuse/enc':>9}")
    for bm, bk, bn in [(8, 27, 128), (8, 144, 128), (8, 288, 128),
                       (32, 32, 32), (64, 128, 64), (128, 256, 128)]:
        fp = ent.tile_footprint_bytes(bm, bk, bn)
        print(f"{bm:>4} {bk:>5} {bn:>5} {fp / 1024:>9.1f} {bn:>9}")
    print("reuse/enc = B-columns sharing one A-tile encode (ASIC row reuse analogue)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--report", action="store_true", help="print the L1 structural report")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    for b in BATCH_SIZES:
        write(os.path.join(args.out, f"tinynet_b{b}.hlo.txt"), to_hlo_text(lower_tinynet(b)))
    for m, k, n in GEMM_SHAPES:
        write(
            os.path.join(args.out, f"gemm_{m}x{k}x{n}.hlo.txt"),
            to_hlo_text(lower_gemm(m, k, n)),
        )
    write(os.path.join(args.out, "encode8.hlo.txt"), to_hlo_text(lower_encoder()))
    # No JAX lowering for the transformer: weights and datapath live in
    # rust (nn::transformer, seeded identically everywhere); the marker
    # registers the artifact so the artifacts backend serves tokens.
    write(
        os.path.join(args.out, "tinyformer.hlo.txt"),
        "// native transformer marker: executed by rust nn::transformer\n",
    )

    if args.report:
        structural_report()
    # Stamp for make's dependency tracking.
    with open(os.path.join(args.out, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()
