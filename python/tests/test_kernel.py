"""L1 correctness: the EN-T Pallas kernel vs the pure-jnp oracle.

Integer arithmetic ⇒ exact equality (assert_array_equal, no tolerance).
Hypothesis sweeps shapes and value distributions including the int8
extremes; dedicated tests pin the corner cases the encoding is most
likely to break on (-128, carry-chain saturation at 255-like patterns).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ent, ref


def np_i8(rng, shape):
    return rng.integers(-128, 128, size=shape, dtype=np.int8)


@pytest.mark.parametrize(
    "m,k,n",
    [(1, 1, 1), (2, 3, 4), (8, 8, 8), (16, 27, 32), (32, 64, 16), (8, 144, 128)],
)
def test_matmul_matches_ref_fixed_shapes(m, k, n):
    rng = np.random.default_rng(42)
    a = jnp.asarray(np_i8(rng, (m, k)))
    b = jnp.asarray(np_i8(rng, (k, n)))
    got = ent.ent_matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_matmul_extreme_operands():
    # -128 is the operand whose magnitude (128) exercises the top digit.
    for fill_a, fill_b in [(-128, -128), (-128, 127), (127, -128), (-1, -1)]:
        a = jnp.full((4, 8), fill_a, jnp.int8)
        b = jnp.full((8, 4), fill_b, jnp.int8)
        got = ent.ent_matmul(a, b)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(ref.matmul_ref(a, b))
        )


def test_matmul_tiled_grid_equals_untiled():
    rng = np.random.default_rng(7)
    a = jnp.asarray(np_i8(rng, (16, 32)))
    b = jnp.asarray(np_i8(rng, (32, 256)))
    whole = ent.ent_matmul(a, b)
    tiled = ent.ent_matmul(a, b, bm=8, bn=64)
    np.testing.assert_array_equal(np.asarray(whole), np.asarray(tiled))


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 12),
    k=st.integers(1, 24),
    n=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_sweep(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(np_i8(rng, (m, k)))
    b = jnp.asarray(np_i8(rng, (k, n)))
    got = ent.ent_matmul(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.matmul_ref(a, b)))


@settings(max_examples=60, deadline=None)
@given(vals=st.lists(st.integers(-128, 127), min_size=1, max_size=64))
def test_encode_wire_bits_match_scalar_ref(vals):
    a = jnp.asarray(np.array(vals, dtype=np.int8))
    got = np.asarray(ent.ent_encode(a))
    want = np.array([ref.wire_bits_ref(int(v)) for v in vals], dtype=np.int32)
    np.testing.assert_array_equal(got, want)


def test_encode_digit_planes_decode_all_int8():
    a = jnp.arange(-128, 128, dtype=jnp.int8)
    sign, planes, cin = ent.encode_digit_planes(a)
    sign = np.asarray(sign)
    planes = [np.asarray(p) for p in planes]
    cin = np.asarray(cin)
    assert np.all(cin == 0), "int8 magnitudes never produce a final carry"
    for p in planes:
        assert p.min() >= -1 and p.max() <= 2, "digit set violation"
    mag = sum(p.astype(np.int64) * 4**i for i, p in enumerate(planes))
    np.testing.assert_array_equal(sign * mag, np.arange(-128, 128))


def test_paper_example_78():
    # Encode(78) = {0, 1, 1, -1, 2}: sign 0, digits (LSB-first) 2,-1,1,1.
    sign, digits, cin = ref.encode_ref(78)
    assert sign is False or sign == 0
    assert digits == [2, -1, 1, 1]
    assert cin == 0
    assert ref.decode_ref(sign, digits, cin) == 78


def test_encode_ref_roundtrip_exhaustive():
    for v in range(-128, 128):
        s, d, c = ref.encode_ref(v)
        assert ref.decode_ref(s, d, c) == v, v
        assert all(-1 <= w <= 2 for w in d), v


def test_tile_footprint_fits_vmem():
    # Every exported tile must fit a 16 MiB VMEM budget comfortably.
    for bm, bk, bn in [(8, 288, 128), (128, 256, 128)]:
        assert ent.tile_footprint_bytes(bm, bk, bn) < 16 * 1024 * 1024


def test_bad_tile_divisibility_rejected():
    a = jnp.zeros((10, 8), jnp.int8)
    b = jnp.zeros((8, 10), jnp.int8)
    with pytest.raises(AssertionError):
        ent.ent_matmul(a, b, bm=4, bn=4)  # 10 % 4 != 0
