"""L2 correctness: the quantized CNN built on the EN-T kernel."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def rand_images(batch, seed=3):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(-128, 128, size=(batch, 3, 32, 32), dtype=np.int8))


def test_forward_shapes_and_dtype():
    x = rand_images(2)
    logits = model.tinynet_forward(x)
    assert logits.shape == (2, 10)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_forward_deterministic():
    x = rand_images(1)
    a = model.tinynet_forward(x)
    b = model.tinynet_forward(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batch_consistency():
    """Each sample's logits must not depend on its batch companions —
    the property the coordinator's padding-based batching relies on."""
    x = rand_images(4, seed=11)
    full = np.asarray(model.tinynet_forward(x))
    for i in range(4):
        solo = np.asarray(model.tinynet_forward(x[i : i + 1]))
        np.testing.assert_array_equal(full[i : i + 1], solo, err_msg=f"sample {i}")


def test_conv_ent_matches_float_conv_reference():
    """conv_ent (im2col + EN-T kernel + requant) vs lax.conv on the same
    integers with identical requantization."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.integers(-128, 128, size=(2, 3, 8, 8), dtype=np.int8))
    w = jnp.asarray(rng.integers(-64, 64, size=(4, 3, 3, 3), dtype=np.int8))
    got = model.conv_ent(x, w, stride=1, pad=1)

    acc = jax.lax.conv_general_dilated(
        x.astype(jnp.int32),
        w.astype(jnp.int32),
        window_strides=(1, 1),
        padding=((1, 1), (1, 1)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    want = jnp.clip(jnp.maximum(acc, 0) >> 7, -128, 127).astype(jnp.int8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_im2col_patch_count():
    x = rand_images(1)
    cols, (ho, wo) = model.im2col(x, kernel=3, stride=2, pad=1)
    assert (ho, wo) == (16, 16)
    assert cols.shape == (3 * 9, 1 * 16 * 16)


def test_pad2_is_value_preserving():
    a = jnp.arange(6, dtype=jnp.int8).reshape(2, 3)
    p = model.pad2(a, 8, 4)
    assert p.shape == (8, 4)
    np.testing.assert_array_equal(np.asarray(p[:2, :3]), np.asarray(a))
    assert int(jnp.abs(p).sum()) == int(jnp.abs(a).sum())
    # GEMM padding invariance end-to-end:
    b = jnp.ones((3, 5), jnp.int8)
    want = np.asarray(ref.matmul_ref(a, b))
    from compile.kernels import ent

    got = np.asarray(ent.ent_matmul(model.pad2(a, 8, 1), model.pad2(b, 1, 8)))
    np.testing.assert_array_equal(got[:2, :5], want)


def test_weights_are_deterministic_across_processes():
    w1 = model.make_weights()
    w2 = model.make_weights()
    for k in w1:
        np.testing.assert_array_equal(np.asarray(w1[k]), np.asarray(w2[k]))
    # Not all-zero / not saturated.
    c1 = np.asarray(w1["conv1"])
    assert c1.std() > 1.0
    assert (np.abs(c1) == 127).mean() < 0.2


@pytest.mark.parametrize("batch", [1, 2])
def test_jit_lowering_round_trip(batch):
    """The exact lowering path aot.py uses must produce parseable HLO
    text with the right entry signature."""
    from compile import aot

    text = aot.to_hlo_text(aot.lower_tinynet(batch))
    assert "ENTRY" in text
    assert f"s8[{batch},3,32,32]" in text
