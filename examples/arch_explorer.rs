//! Architecture explorer — sweep the five TCU microarchitectures across
//! sizes and variants, printing the area/power grid (Fig 6) and the
//! efficiency up-ratios (Fig 7) plus the cost breakdown per component.
//!
//! Run: `cargo run --release --example arch_explorer [-- --json]`

use ent::arch::{Scale, Tcu, ALL_ARCHS, ALL_SCALES};
use ent::pe::{Variant, ALL_VARIANTS};
use ent::util::json::Json;
use ent::util::table::{f, pct, Table};

fn main() {
    let json_mode = std::env::args().any(|a| a == "--json");
    let mut json_rows = Vec::new();

    for arch in ALL_ARCHS {
        let mut t = Table::new(format!("\n=== {} ===", arch.name())).header(&[
            "size", "variant", "area mm2", "power mW", "GOPS", "GOPS/mm2", "GOPS/W",
            "Δarea-eff", "Δenergy-eff",
        ]);
        for scale in ALL_SCALES {
            let s = arch.size_for_scale(scale);
            let base = Tcu::new(arch, s, Variant::Baseline);
            for variant in ALL_VARIANTS {
                let tcu = Tcu::new(arch, s, variant);
                let c = tcu.cost().total();
                let d_ae = tcu.area_efficiency() / base.area_efficiency() - 1.0;
                let d_ee = tcu.energy_efficiency() / base.energy_efficiency() - 1.0;
                t.row(vec![
                    format!("{s}"),
                    variant.name().into(),
                    f(c.area_um2 / 1e6, 3),
                    f(c.power_uw / 1e3, 1),
                    f(tcu.gops(), 0),
                    f(tcu.area_efficiency(), 0),
                    f(tcu.energy_efficiency(), 0),
                    pct(d_ae),
                    pct(d_ee),
                ]);
                json_rows.push(Json::obj(vec![
                    ("arch", Json::str(arch.short_name())),
                    ("size", Json::num(s as f64)),
                    ("variant", Json::str(variant.name())),
                    ("area_um2", Json::num(c.area_um2)),
                    ("power_uw", Json::num(c.power_uw)),
                    ("d_area_eff", Json::num(d_ae)),
                    ("d_energy_eff", Json::num(d_ee)),
                ]));
            }
        }
        if !json_mode {
            print!("{}", t.render());
        }
    }

    if json_mode {
        println!("{}", Json::Arr(json_rows));
        return;
    }

    // Fig 7-style summary: average up-ratio per scale for EN-T(Ours).
    let mut t = Table::new("\n=== Fig 7 summary: EN-T(Ours) average up-ratios ===")
        .header(&["scale", "avg Δarea-eff", "avg Δenergy-eff"]);
    for scale in ALL_SCALES {
        let (mut sa, mut se) = (0.0, 0.0);
        for arch in ALL_ARCHS {
            let s = arch.size_for_scale(scale);
            let b = Tcu::new(arch, s, Variant::Baseline);
            let e = Tcu::new(arch, s, Variant::EntOurs);
            sa += e.area_efficiency() / b.area_efficiency() - 1.0;
            se += e.energy_efficiency() / b.energy_efficiency() - 1.0;
        }
        t.row(vec![
            scale.name().into(),
            pct(sa / ALL_ARCHS.len() as f64),
            pct(se / ALL_ARCHS.len() as f64),
        ]);
    }
    print!("{}", t.render());
    println!("\npaper: area +8.7%/+12.2%/+11.0%, energy +13.0%/+17.5%/+15.5%");

    // Component breakdown at the SoC's 1-TOPS operating point.
    let mut t = Table::new("\n=== Cost breakdown @1 TOPS, EN-T(Ours) ===").header(&[
        "arch", "mults", "regs", "accs", "trees", "encoders", "routing", "total mm2",
    ]);
    for arch in ALL_ARCHS {
        let s = arch.size_for_scale(Scale::Tops1);
        let c = Tcu::new(arch, s, Variant::EntOurs).cost();
        t.row(vec![
            arch.name().into(),
            f(c.mults.area_um2 / 1e6, 3),
            f(c.registers.area_um2 / 1e6, 3),
            f(c.accumulators.area_um2 / 1e6, 3),
            f(c.adder_trees.area_um2 / 1e6, 3),
            f(c.encoders.area_um2 / 1e6, 4),
            f(c.routing.area_um2 / 1e6, 3),
            f(c.total().area_um2 / 1e6, 3),
        ]);
    }
    print!("{}", t.render());
}
