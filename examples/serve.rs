//! Serving demo with a live load generator: open-loop arrivals at a
//! configurable rate against the coordinator, demonstrating the dynamic
//! batcher forming larger batches as pressure grows.
//!
//! Run: `cargo run --release --example serve [-- <req_per_sec> <seconds>]`

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use ent::coordinator::{Config, Coordinator, InferRequest};
use ent::util::prng::Rng;

fn main() -> ent::Result<()> {
    let rate: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200.0);
    let seconds: f64 = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(3.0);

    let coord = Coordinator::start(Config::default())?;
    let input_len = coord.model().input_len();
    println!("open-loop load: {rate:.0} req/s for {seconds:.0}s");

    let inflight = AtomicUsize::new(0);
    let completed = AtomicUsize::new(0);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        // Load generator: fires requests on a steady clock, each handled
        // by a short-lived waiter thread (open loop — arrivals don't
        // wait for completions).
        let coord_ref = &coord;
        let inflight_ref = &inflight;
        let completed_ref = &completed;
        scope.spawn(move || {
            let mut rng = Rng::new(0x10AD);
            let period = Duration::from_secs_f64(1.0 / rate);
            let mut next = Instant::now();
            while t0.elapsed().as_secs_f64() < seconds {
                let img = rng.i8_vec(input_len);
                let rx = coord_ref.submit(InferRequest { image: img });
                inflight_ref.fetch_add(1, Ordering::Relaxed);
                scope.spawn(move || {
                    let _ = rx.recv();
                    inflight_ref.fetch_sub(1, Ordering::Relaxed);
                    completed_ref.fetch_add(1, Ordering::Relaxed);
                });
                next += period;
                if let Some(sleep) = next.checked_duration_since(Instant::now()) {
                    std::thread::sleep(sleep);
                }
            }
        });
        // Progress reporter.
        scope.spawn(move || {
            while t0.elapsed().as_secs_f64() < seconds + 0.5 {
                std::thread::sleep(Duration::from_millis(500));
                let m = coord_ref.metrics();
                println!(
                    "t={:>4.1}s  done {:>5}  inflight {:>3}  mean batch {:.2}",
                    t0.elapsed().as_secs_f64(),
                    completed_ref.load(Ordering::Relaxed),
                    inflight_ref.load(Ordering::Relaxed),
                    m.mean_batch
                );
            }
        });
    });

    let m = coord.metrics();
    println!(
        "\nfinal: {} requests, {} errors, mean batch {:.2}",
        m.requests, m.errors, m.mean_batch
    );
    if let Some(lat) = m.latency_us {
        println!(
            "latency µs: mean {:.0}  p50 {:.0}  p95 {:.0}  p99 {:.0}",
            lat.mean, lat.median, lat.p95, lat.p99
        );
    }
    coord.shutdown();
    Ok(())
}
