//! Quickstart — the 60-second tour of the EN-T library.
//!
//! 1. Encode a value with the paper's carry-chain encoding (Eq. 7–17).
//! 2. Multiply through the encoder-hoisted (RME) datapath, bit-exact.
//! 3. Run a matmul through an EN-T systolic array and check it.
//! 4. Compare baseline vs EN-T TCU cost at the paper's 1-TOPS point.
//! 5. If `make artifacts` has run: execute the AOT-compiled Pallas GEMM
//!    through PJRT and cross-check it against the rust datapath.
//!
//! Run: `cargo run --release --example quickstart`

use ent::arch::{gemm_ref, ArchKind, Tcu};
use ent::arith::multiplier::{MultKind, Multiplier};
use ent::encoding::ent::encode_signed;
use ent::pe::Variant;
use ent::runtime::{default_artifact_dir, Runtime};
use ent::sim::tiled_matmul;
use ent::util::prng::Rng;

fn main() -> ent::Result<()> {
    // 1. The paper's worked example: Encode(78) = {0, 1, 1, -1, 2}.
    let code = encode_signed(78, 8);
    println!(
        "Encode(78): sign={} digits(LSB→MSB)={:?} → {} wire bits",
        code.sign as u8,
        code.mag.digits,
        9
    );
    assert_eq!(code.mag.digits, vec![2, -1, 1, 1]);

    // 2. Multiply through the hoisted-encoder datapath.
    let rme = Multiplier::new(MultKind::EntRme, 8);
    let product = rme.mul_encoded(&code, -55);
    println!("78 × -55 through the EN-T PE datapath = {product}");
    assert_eq!(product, 78 * -55);

    // 3. A matmul through the EN-T output-stationary systolic array.
    let tcu = Tcu::new(ArchKind::SystolicOs, 16, Variant::EntOurs);
    let mut rng = Rng::new(1);
    let (m, k, n) = (24, 40, 24);
    let a = rng.i8_vec(m * k);
    let b = rng.i8_vec(k * n);
    let c = tiled_matmul(&tcu, &a, &b, m, k, n);
    assert_eq!(c, gemm_ref(&a, &b, m, k, n));
    println!("{}x{}x{} GEMM exact through the EN-T systolic dataflow", m, k, n);

    // 4. What EN-T buys at the paper's SoC operating point.
    let base = Tcu::new(ArchKind::SystolicOs, 32, Variant::Baseline);
    let ours = Tcu::new(ArchKind::SystolicOs, 32, Variant::EntOurs);
    println!(
        "1-TOPS systolic TCU: area {:.3} → {:.3} mm², power {:.0} → {:.0} mW \
         (Δ area-eff {:+.1}%, Δ energy-eff {:+.1}%)",
        base.cost().total().area_um2 / 1e6,
        ours.cost().total().area_um2 / 1e6,
        base.cost().total().power_uw / 1e3,
        ours.cost().total().power_uw / 1e3,
        (ours.area_efficiency() / base.area_efficiency() - 1.0) * 100.0,
        (ours.energy_efficiency() / base.energy_efficiency() - 1.0) * 100.0,
    );

    // 5. Cross-layer: the Pallas-kernel artifact through PJRT.
    let dir = default_artifact_dir();
    if dir.join("gemm_32x32x32.hlo.txt").exists() {
        let mut rt = Runtime::cpu()?;
        rt.load_file("gemm", &dir.join("gemm_32x32x32.hlo.txt"))?;
        let a = rng.i8_vec(32 * 32);
        let b = rng.i8_vec(32 * 32);
        let via_pjrt = rt.gemm_i8("gemm", &a, &b, 32, 32, 32)?;
        let reference = gemm_ref(&a, &b, 32, 32, 32);
        assert!(via_pjrt
            .iter()
            .zip(&reference)
            .all(|(&x, &y)| x as i64 == y));
        println!("AOT Pallas GEMM through PJRT matches the rust datapath exactly");
    } else {
        println!("(artifacts not built — run `make artifacts` for the PJRT demo)");
    }

    println!("quickstart: OK");
    Ok(())
}
