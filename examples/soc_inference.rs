//! End-to-end validation driver (DESIGN.md §"End-to-end validation"):
//! serve batched inference requests through the full three-layer stack —
//! AOT-compiled Pallas CNN → PJRT runtime → coordinator with dynamic
//! batching — while the SoC digital twin replays the paper's §4.4
//! benchmark, and report latency/throughput plus the paper's headline
//! metric (EN-T energy reduction) for the same run.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example soc_inference [-- <requests>]`

use std::time::Instant;

use ent::arch::{ArchKind, ALL_ARCHS};
use ent::coordinator::{Config, Coordinator, InferRequest};
use ent::nn::zoo;
use ent::pe::Variant;
use ent::soc::{energy, Soc};
use ent::util::prng::Rng;
use ent::util::table::{f, pct, Table};

fn main() -> ent::Result<()> {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);

    // --- Phase 1: real serving through the AOT artifacts ---
    println!("== phase 1: serving {n_requests} real requests (tinynet, int8, PJRT) ==");
    let coord = Coordinator::start(Config::default())?;
    let input_len = coord.model().input_len();
    let t0 = Instant::now();
    let clients = 4;
    std::thread::scope(|scope| {
        for c in 0..clients {
            let coord = &coord;
            scope.spawn(move || {
                let mut rng = Rng::new(0xE2E + c as u64);
                for _ in 0..n_requests / clients {
                    let r = coord
                        .infer(InferRequest {
                            image: rng.i8_vec(input_len),
                        })
                        .expect("inference");
                    assert_eq!(r.logits.len(), 10);
                }
            });
        }
    });
    let wall = t0.elapsed();
    let m = coord.metrics();
    println!(
        "served {} requests in {:.1} ms  →  {:.0} req/s, mean batch {:.2}, errors {}",
        m.requests,
        wall.as_secs_f64() * 1e3,
        m.requests as f64 / wall.as_secs_f64(),
        m.mean_batch,
        m.errors
    );
    if let Some(lat) = m.latency_us {
        println!(
            "request latency µs: mean {:.0}  p50 {:.0}  p95 {:.0}  p99 {:.0}",
            lat.mean, lat.median, lat.p95, lat.p99
        );
    }
    let sample = coord.infer(InferRequest {
        image: Rng::new(9).i8_vec(input_len),
    })?;
    println!(
        "digital twin per frame: {:.2} µJ, {:.3} ms on the modelled EN-T SoC",
        sample.sim_energy_uj, sample.sim_latency_ms
    );
    coord.shutdown();

    // --- Phase 2: the paper's SoC benchmark on the same stack's models ---
    println!("\n== phase 2: §4.4 SoC benchmark replay (headline metric) ==");
    let mut t = Table::new("single-frame energy, ResNet50 (1,3,224,224)").header(&[
        "arch", "baseline mJ", "EN-T(Ours) mJ", "reduction", "latency ms",
    ]);
    let net = zoo::by_name("resnet50").unwrap();
    for arch in ALL_ARCHS {
        let base = energy::frame_energy(&Soc::paper_config(arch, Variant::Baseline), &net).0;
        let ours = energy::frame_energy(&Soc::paper_config(arch, Variant::EntOurs), &net).0;
        t.row(vec![
            arch.name().into(),
            f(base.total_mj(), 2),
            f(ours.total_mj(), 2),
            pct(1.0 - ours.total_pj() / base.total_pj()),
            f(ours.latency_ms(), 1),
        ]);
    }
    print!("{}", t.render());
    println!(
        "paper Fig 11 (same metric): 2D Matrix 15.1–15.9%, SA-OS 11.3–12.8%, \
         SA-WS 10.2–11.7%, 1D/2D 14.0–16.0%, Cube 5.0–6.0%"
    );
    let _ = ArchKind::Matrix2d;
    println!("\nsoc_inference: OK (record this run in EXPERIMENTS.md)");
    Ok(())
}
